//! Deterministic fault injection for the chaos suite (the `failpoints`
//! cargo feature).
//!
//! The robustness layer's claims — a panicking band fails only its own
//! ticket, certified mode never ships an uncertified result, the pool
//! and panel cache survive member failures — are only testable if the
//! failures can be *provoked on demand*.  This module plants named
//! injection sites at the seven failure domains:
//!
//! | site               | where it fires                                   | effect            |
//! |--------------------|--------------------------------------------------|-------------------|
//! | `worker_panic`     | per-member band task of the fused batch sweep    | `panic!`          |
//! | `slice_overflow`   | INT8 sweep entry ([`crate::kernels::int8`])      | `Error::Numerical`|
//! | `cache_corrupt`    | packed-panel cache hit ([`crate::ozaki`] prepare)| forced repack     |
//! | `probe_fail`       | dispatcher FP64 row probe                        | `Error::Numerical`|
//! | `offload_error`    | device offload submission                        | `Error::Xla`      |
//! | `offload_timeout`  | device offload submission                        | `Error::Timeout`  |
//! | `offload_transient`| device offload submission                        | `Error::Xla`      |
//!
//! Firing is **deterministic**: each armed site draws from
//! [`crate::util::rng::mix64`] over `seed ⊕ site-tag ⊕ draw-ordinal`,
//! so a given `(prob, seed)` arming fires on exactly the same draws in
//! every run, on every thread.  Sites are armed programmatically
//! ([`arm`] / [`arm_limited`] / [`disarm_all`], used by the chaos
//! tests) or from the environment:
//! `OZACCEL_FAULTS=site:prob:seed[:limit][,site:prob:seed[:limit]...]`,
//! e.g. `OZACCEL_FAULTS=worker_panic:0.25:7,offload_transient:1:3:2`.
//! The optional `limit` caps how many times the site fires before it
//! goes quiet — `offload_transient:1:3:2` fails the first two draws and
//! then succeeds forever, the canonical transient-device-glitch shape
//! the retry layer must absorb.
//!
//! Without the `failpoints` feature every probe compiles to a constant
//! `false` (the hooks cost nothing on release builds) and
//! `OZACCEL_FAULTS` is ignored.

use crate::error::{Error, Result};

/// A named fault-injection site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Panic inside a worker-pool band task (one batch member's band).
    WorkerPanic,
    /// INT8 slice-accumulator overflow reported by the fused sweep.
    SliceOverflow,
    /// Packed-panel cache corruption: a hit is treated as detected
    /// corruption and repacked (results stay bit-identical).
    CacheCorrupt,
    /// The a-posteriori FP64 row probe fails.
    ProbeFail,
    /// The device offload submission fails (hard backend error).
    OffloadError,
    /// The device offload submission exceeds its deadline.
    OffloadTimeout,
    /// A transient device glitch: fails like `offload_error` but is
    /// normally armed with a fire `limit` so retries eventually succeed.
    OffloadTransient,
}

impl FaultSite {
    /// Every site, in table order.
    pub const ALL: [FaultSite; 7] = [
        FaultSite::WorkerPanic,
        FaultSite::SliceOverflow,
        FaultSite::CacheCorrupt,
        FaultSite::ProbeFail,
        FaultSite::OffloadError,
        FaultSite::OffloadTimeout,
        FaultSite::OffloadTransient,
    ];

    /// Canonical snake_case name (the `OZACCEL_FAULTS` spelling).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::WorkerPanic => "worker_panic",
            FaultSite::SliceOverflow => "slice_overflow",
            FaultSite::CacheCorrupt => "cache_corrupt",
            FaultSite::ProbeFail => "probe_fail",
            FaultSite::OffloadError => "offload_error",
            FaultSite::OffloadTimeout => "offload_timeout",
            FaultSite::OffloadTransient => "offload_transient",
        }
    }

    /// Parse a canonical site name (loud on anything else).
    pub fn parse(s: &str) -> Result<Self> {
        let want = s.trim().to_ascii_lowercase();
        FaultSite::ALL
            .into_iter()
            .find(|site| site.name() == want)
            .ok_or_else(|| {
                Error::Config(format!(
                    "bad fault site {s:?} (expected one of worker_panic | slice_overflow \
                     | cache_corrupt | probe_fail | offload_error | offload_timeout \
                     | offload_transient)"
                ))
            })
    }

    #[cfg(feature = "failpoints")]
    fn index(self) -> usize {
        FaultSite::ALL.iter().position(|&s| s == self).unwrap()
    }

    /// Stable per-site salt folded into the deterministic draw.
    #[cfg(feature = "failpoints")]
    fn tag(self) -> u64 {
        // FNV-1a over the site name: stable across reorderings.
        self.name()
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
            })
    }
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(feature = "failpoints")]
mod plan {
    use super::FaultSite;
    use std::sync::{Mutex, OnceLock};

    #[derive(Clone, Copy, Default)]
    pub(super) struct Arm {
        pub prob: f64,
        pub seed: u64,
        pub draws: u64,
        pub fired: u64,
        /// Stop firing after this many hits (`None` = unlimited); the
        /// transient-fault shape: fail N draws, then succeed forever.
        pub limit: Option<u64>,
    }

    pub(super) fn registry() -> &'static Mutex<[Option<Arm>; 7]> {
        static PLAN: OnceLock<Mutex<[Option<Arm>; 7]>> = OnceLock::new();
        PLAN.get_or_init(|| {
            let mut sites: [Option<Arm>; 7] = [None; 7];
            if let Ok(spec) = std::env::var("OZACCEL_FAULTS") {
                for (site, prob, seed, limit) in super::parse_spec(&spec).unwrap_or_else(|e| {
                    crate::util::env::invalid(
                        "OZACCEL_FAULTS",
                        &spec,
                        &format!("site:prob:seed[:limit][,site:prob:seed[:limit]...] — {e}"),
                    )
                }) {
                    sites[site.index()] = Some(Arm {
                        prob,
                        seed,
                        draws: 0,
                        fired: 0,
                        limit,
                    });
                }
            }
            Mutex::new(sites)
        })
    }
}

/// Parse an `OZACCEL_FAULTS` specification into `(site, prob, seed,
/// limit)` tuples.  `prob` must be a finite value in `[0, 1]`; `seed` a
/// u64; the optional fourth field caps how many times the site fires.
pub fn parse_spec(spec: &str) -> Result<Vec<(FaultSite, f64, u64, Option<u64>)>> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':');
        let (site, prob, seed, limit) = (parts.next(), parts.next(), parts.next(), parts.next());
        if parts.next().is_some() {
            return Err(Error::Config(format!(
                "bad fault entry {entry:?} (expected site:prob:seed[:limit])"
            )));
        }
        let site = FaultSite::parse(site.unwrap_or(""))?;
        let prob: f64 = prob
            .unwrap_or("")
            .trim()
            .parse()
            .map_err(|_| Error::Config(format!("bad fault probability in {entry:?}")))?;
        if !(0.0..=1.0).contains(&prob) {
            return Err(Error::Config(format!(
                "fault probability {prob} in {entry:?} outside [0, 1]"
            )));
        }
        let seed: u64 = seed
            .unwrap_or("")
            .trim()
            .parse()
            .map_err(|_| Error::Config(format!("bad fault seed in {entry:?}")))?;
        let limit = limit
            .map(|raw| {
                raw.trim()
                    .parse::<u64>()
                    .map_err(|_| Error::Config(format!("bad fault fire limit in {entry:?}")))
            })
            .transpose()?;
        out.push((site, prob, seed, limit));
    }
    Ok(out)
}

/// Arm `site` to fire with probability `prob` on a deterministic
/// sequence derived from `seed` (resets the site's draw/fired
/// counters).  No-op without the `failpoints` feature.
pub fn arm(site: FaultSite, prob: f64, seed: u64) {
    #[cfg(feature = "failpoints")]
    {
        plan::registry().lock().unwrap()[site.index()] = Some(plan::Arm {
            prob: prob.clamp(0.0, 1.0),
            seed,
            draws: 0,
            fired: 0,
            limit: None,
        });
    }
    #[cfg(not(feature = "failpoints"))]
    let _ = (site, prob, seed);
}

/// [`arm`] with a fire cap: the site fires at most `limit` times and
/// then goes quiet — `arm_limited(OffloadTransient, 1.0, 0, 2)` fails
/// the first two offload attempts and lets every later one through.
/// No-op without the `failpoints` feature.
pub fn arm_limited(site: FaultSite, prob: f64, seed: u64, limit: u64) {
    #[cfg(feature = "failpoints")]
    {
        plan::registry().lock().unwrap()[site.index()] = Some(plan::Arm {
            prob: prob.clamp(0.0, 1.0),
            seed,
            draws: 0,
            fired: 0,
            limit: Some(limit),
        });
    }
    #[cfg(not(feature = "failpoints"))]
    let _ = (site, prob, seed, limit);
}

/// Disarm every site (chaos tests call this between scenarios).
pub fn disarm_all() {
    #[cfg(feature = "failpoints")]
    for slot in plan::registry().lock().unwrap().iter_mut() {
        *slot = None;
    }
}

/// How many times `site` has fired since it was (re-)armed.
pub fn fired(site: FaultSite) -> u64 {
    #[cfg(feature = "failpoints")]
    {
        return plan::registry().lock().unwrap()[site.index()]
            .map(|a| a.fired)
            .unwrap_or(0);
    }
    #[cfg(not(feature = "failpoints"))]
    {
        let _ = site;
        0
    }
}

/// Draw the site's next deterministic sample and report whether the
/// fault fires.  Always `false` without the `failpoints` feature.
#[inline]
pub fn should_fire(site: FaultSite) -> bool {
    #[cfg(feature = "failpoints")]
    {
        let mut sites = plan::registry().lock().unwrap();
        if let Some(arm) = sites[site.index()].as_mut() {
            if arm.limit.is_some_and(|cap| arm.fired >= cap) {
                return false;
            }
            arm.draws += 1;
            let word = crate::util::rng::mix64(arm.seed ^ site.tag() ^ arm.draws);
            let u = (word >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            if u < arm.prob {
                arm.fired += 1;
                return true;
            }
        }
        false
    }
    #[cfg(not(feature = "failpoints"))]
    {
        let _ = site;
        false
    }
}

/// Panic here when `site` fires (the worker-panic injection hook).
#[inline]
pub fn maybe_panic(site: FaultSite) {
    if should_fire(site) {
        panic!("ozaccel fault injection: {}", site.name());
    }
}

/// Fail here when `site` fires; `make_err` shapes the injected error so
/// each site surfaces through its natural error variant.
#[inline]
pub fn maybe_fail(site: FaultSite, make_err: impl FnOnce(String) -> Error) -> Result<()> {
    if should_fire(site) {
        Err(make_err(format!("injected fault: {}", site.name())))
    } else {
        Ok(())
    }
}

/// Serialize tests and chaos scenarios that arm the process-global
/// registry (the test harness runs cases concurrently; two armed plans
/// interleaving would make the deterministic draws meaningless).
/// Poisoning is ignored — a failed scenario must not cascade.
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_lock() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn site_names_roundtrip_and_reject() {
        for s in FaultSite::ALL {
            assert_eq!(FaultSite::parse(s.name()).unwrap(), s);
            assert_eq!(format!("{s}"), s.name());
        }
        for bad in ["", "panic", "worker-panic", "cache"] {
            assert!(FaultSite::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn spec_parses_and_rejects() {
        let plan = parse_spec("worker_panic:0.25:7, probe_fail:1:3").unwrap();
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].0, FaultSite::WorkerPanic);
        assert_eq!(plan[0].1, 0.25);
        assert_eq!(plan[1].2, 3);
        assert_eq!(plan[0].3, None);
        // The optional fourth field is a fire limit (transient faults).
        let plan = parse_spec("offload_transient:1:3:2").unwrap();
        assert_eq!(plan[0].0, FaultSite::OffloadTransient);
        assert_eq!(plan[0].3, Some(2));
        assert!(parse_spec("").unwrap().is_empty());
        for bad in [
            "worker_panic",
            "worker_panic:0.5",
            "worker_panic:2:1",
            "worker_panic:x:1",
            "worker_panic:0.5:y",
            "worker_panic:0.5:1:z",
            "worker_panic:0.5:1:9:2",
            "bogus:0.5:1",
        ] {
            assert!(parse_spec(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn armed_sites_fire_deterministically() {
        let _g = plan_lock();
        arm(FaultSite::ProbeFail, 0.5, 42);
        let first: Vec<bool> = (0..64).map(|_| should_fire(FaultSite::ProbeFail)).collect();
        let hits = fired(FaultSite::ProbeFail);
        assert!(hits > 10 && hits < 54, "p=0.5 should fire ~half: {hits}");
        arm(FaultSite::ProbeFail, 0.5, 42); // re-arm resets the sequence
        let second: Vec<bool> = (0..64).map(|_| should_fire(FaultSite::ProbeFail)).collect();
        assert_eq!(first, second, "same (prob, seed) must fire identically");
        disarm_all();
        assert!(!should_fire(FaultSite::ProbeFail));
        assert_eq!(fired(FaultSite::ProbeFail), 0);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn probability_extremes_always_and_never_fire() {
        let _g = plan_lock();
        arm(FaultSite::OffloadError, 1.0, 1);
        assert!((0..32).all(|_| should_fire(FaultSite::OffloadError)));
        arm(FaultSite::OffloadError, 0.0, 1);
        assert!((0..32).all(|_| !should_fire(FaultSite::OffloadError)));
        disarm_all();
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn limited_arms_fire_exactly_n_times_then_go_quiet() {
        let _g = plan_lock();
        arm_limited(FaultSite::OffloadTransient, 1.0, 0, 3);
        let hits: Vec<bool> = (0..8).map(|_| should_fire(FaultSite::OffloadTransient)).collect();
        assert_eq!(hits, [true, true, true, false, false, false, false, false]);
        assert_eq!(fired(FaultSite::OffloadTransient), 3);
        disarm_all();
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let _g = plan_lock();
        // Without the feature this also pins the no-op compile path.
        assert!(!should_fire(FaultSite::CacheCorrupt));
        maybe_panic(FaultSite::CacheCorrupt); // must not panic unarmed
        assert!(maybe_fail(FaultSite::CacheCorrupt, Error::Numerical).is_ok());
    }
}
