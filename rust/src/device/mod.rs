//! Batched device execution pipeline.
//!
//! The seed offload path is strictly call-at-a-time: every routed GEMM
//! pays its own admission, transfer, and submission overhead, which is
//! exactly the per-call cost the paper's emulation amortises away on
//! real accelerators.  This subsystem gives the batch engine a device
//! path with the same amortisation story, in three pieces:
//!
//! * [`artifact`] — one compiled artifact per engine bucket
//!   (shape × mode × splits × backend) executing **all** members' slice
//!   products in a single submission, cached content-addressed with LRU
//!   eviction ([`ArtifactCache`]).
//! * [`staging`] — an async H2D staging pipeline ([`run_staged`]):
//!   split/pack of bucket *k+1* overlaps execution of bucket *k*,
//!   with bounded buffers and backpressure.
//! * [`throughput`] — measured-throughput routing input
//!   ([`ThroughputTracker`]): per-site EWMAs of observed host vs device
//!   flop/s and bytes/s feed `RoutingPolicy::decide`, demoting the
//!   static `perfmodel` tables to a cold-start prior.
//!
//! Everything here runs fully against the `sim` backend (which computes
//! through the host kernels), so the whole pipeline is CI-testable
//! today; the PJRT backend reports batched submission as typed
//! `Unimplemented` and falls back per-call.

pub mod artifact;
pub mod staging;
pub mod throughput;

pub use artifact::{ArtifactCache, ArtifactCacheStats, ArtifactKey, DeviceArtifact};
pub use staging::{run_staged, StageTiming, StagingStats};
pub use throughput::{SiteThroughput, ThroughputTracker, FLIP_MARGIN, MIN_SAMPLES};
