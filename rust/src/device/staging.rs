//! Async H2D staging pipeline: overlap bucket *k+1*'s split/pack with
//! bucket *k*'s execution.
//!
//! The engine's flush hands this module the ordered list of device
//! buckets; a dedicated staging thread runs the (CPU-bound) Ozaki
//! split/pack — the emulation's host-to-device preparation — and feeds
//! staged buckets through a bounded channel to the caller's thread,
//! which executes submissions in order.  The channel bound
//! ([`crate::resilience::OffloadConfig::staging_depth`], `[offload]
//! staging_depth`) is the backpressure: the stager blocks once `depth`
//! buckets are prepared-but-unexecuted, so staging buffers stay bounded
//! no matter how deep the flush is.
//!
//! Determinism contract: the execute callback runs on the *calling*
//! thread, strictly in item order — fault-injection draws and
//! per-member fallback decisions therefore happen in the same order as
//! the sequential path, and results are bit-identical regardless of
//! staging interleaving.  A panic inside a stage callback is caught and
//! surfaced to the execute callback as an `Err(message)` for that item;
//! later items still stage and execute.
//!
//! Per-item [`StageTiming`] separates time spent staging from time the
//! executor spent *waiting* on the stager: staging time not waited on
//! is transfer/compute overlap, the quantity `BENCH_device.json`
//! reports.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::sync_channel;
use std::time::Instant;

use crate::kernels::int8::panic_message;

/// Where one item's staging time went, as seen by the executor.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTiming {
    /// Nanoseconds the staging thread spent preparing this item.
    pub stage_ns: u64,
    /// Nanoseconds the executor blocked waiting for this item.
    pub wait_ns: u64,
}

impl StageTiming {
    /// Staging nanoseconds hidden behind execution of earlier items —
    /// the overlap the pipeline exists to create.
    pub fn overlap_ns(&self) -> u64 {
        self.stage_ns.saturating_sub(self.wait_ns)
    }
}

/// Aggregate staging counters for one flush.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StagingStats {
    /// Items staged (successfully or not).
    pub staged: u64,
    /// Total staging-thread nanoseconds.
    pub stage_ns: u64,
    /// Total executor-side wait nanoseconds.
    pub wait_ns: u64,
}

impl StagingStats {
    /// Total staging nanoseconds hidden behind execution.
    pub fn overlap_ns(&self) -> u64 {
        self.stage_ns.saturating_sub(self.wait_ns)
    }
}

/// Run `items` through a two-stage pipeline: `stage` on a dedicated
/// thread (at most `depth` items ahead of execution), `exec` on the
/// calling thread in item order.  A staging panic reaches `exec` as
/// `Err(panic message)` for that item.  Returns the per-item results
/// and the flush's aggregate [`StagingStats`].
pub fn run_staged<I, S, R>(
    depth: usize,
    items: Vec<I>,
    mut stage: impl FnMut(I) -> S + Send,
    mut exec: impl FnMut(Result<S, String>, StageTiming) -> R,
) -> (Vec<R>, StagingStats)
where
    I: Send,
    S: Send,
{
    let mut results = Vec::with_capacity(items.len());
    let mut stats = StagingStats::default();
    let count = items.len();
    std::thread::scope(|scope| {
        let (tx, rx) = sync_channel::<(Result<S, String>, u64)>(depth.max(1));
        scope.spawn(move || {
            for item in items {
                let t0 = Instant::now();
                let staged = catch_unwind(AssertUnwindSafe(|| stage(item)))
                    .map_err(|p| format!("staging panicked: {}", panic_message(&*p)));
                let stage_ns = t0.elapsed().as_nanos() as u64;
                if tx.send((staged, stage_ns)).is_err() {
                    // executor gone (it never drops early today; belt
                    // and braces against future early exits)
                    return;
                }
            }
        });
        for _ in 0..count {
            let t0 = Instant::now();
            let Ok((staged, stage_ns)) = rx.recv() else {
                break;
            };
            let wait_ns = t0.elapsed().as_nanos() as u64;
            stats.staged += 1;
            stats.stage_ns += stage_ns;
            stats.wait_ns += wait_ns;
            results.push(exec(staged, StageTiming { stage_ns, wait_ns }));
        }
    });
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_in_item_order_on_the_calling_thread() {
        let caller = std::thread::current().id();
        let (results, stats) = run_staged(
            2,
            vec![1u32, 2, 3, 4, 5],
            |i| i * 10,
            |staged, _| {
                assert_eq!(std::thread::current().id(), caller);
                staged.unwrap()
            },
        );
        assert_eq!(results, vec![10, 20, 30, 40, 50]);
        assert_eq!(stats.staged, 5);
    }

    #[test]
    fn staging_panic_reaches_exec_as_an_error_and_later_items_survive() {
        let (results, stats) = run_staged(
            1,
            vec![1u32, 2, 3],
            |i| {
                if i == 2 {
                    panic!("boom on {i}");
                }
                i
            },
            |staged, _| staged,
        );
        assert_eq!(results[0], Ok(1));
        let err = results[1].as_ref().unwrap_err();
        assert!(
            err.contains("staging panicked") && err.contains("boom on 2"),
            "got: {err}"
        );
        assert_eq!(results[2], Ok(3), "items after a panic still stage");
        assert_eq!(stats.staged, 3);
    }

    #[test]
    fn backpressure_bounds_how_far_staging_runs_ahead() {
        // With depth 1, the stager can be at most 2 items past the last
        // executed one (1 in the channel + 1 being staged).
        static STAGED: AtomicUsize = AtomicUsize::new(0);
        static EXECED: AtomicUsize = AtomicUsize::new(0);
        STAGED.store(0, Ordering::SeqCst);
        EXECED.store(0, Ordering::SeqCst);
        let (_, stats) = run_staged(
            1,
            (0..16usize).collect(),
            |i| {
                STAGED.fetch_add(1, Ordering::SeqCst);
                i
            },
            |staged, _| {
                // slow executor: give the stager every chance to race ahead
                std::thread::sleep(std::time::Duration::from_millis(1));
                let ahead =
                    STAGED.load(Ordering::SeqCst) - EXECED.fetch_add(1, Ordering::SeqCst) - 1;
                assert!(ahead <= 3, "stager ran {ahead} items ahead of depth-1 bound");
                staged.unwrap()
            },
        );
        assert_eq!(stats.staged, 16);
    }

    #[test]
    fn overlap_accounting_subtracts_executor_waits() {
        let t = StageTiming {
            stage_ns: 1000,
            wait_ns: 400,
        };
        assert_eq!(t.overlap_ns(), 600);
        let fully_waited = StageTiming {
            stage_ns: 300,
            wait_ns: 900,
        };
        assert_eq!(fully_waited.overlap_ns(), 0, "saturating, never negative");
        let s = StagingStats {
            staged: 2,
            stage_ns: 1300,
            wait_ns: 1300,
        };
        assert_eq!(s.overlap_ns(), 0);
    }
}
