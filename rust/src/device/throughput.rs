//! Measured-throughput routing input: per-site EWMAs of observed host
//! vs device throughput.
//!
//! The static `perfmodel` tables predict what a *modelled* GPU would
//! do; this tracker records what the attached backend and the host
//! SIMD path *actually* delivered, per call site, as exponentially
//! weighted moving averages of flop/s and bytes/s.  Routing
//! ([`crate::coordinator::RoutingPolicy::decide`]) consults it as its
//! last, lazy predicate: a site whose measured host throughput clearly
//! beats the device's flips to [`crate::coordinator::OffloadDecision::
//! HostMeasured`], with the static tables demoted to cold-start priors.
//!
//! Flip hygiene: a site only flips once **both** routes have at least
//! [`MIN_SAMPLES`] observations (an EWMA needs warm-up — deciding off
//! one noisy measurement would thrash), and only when the host is at
//! least 2× faster than the device estimate (hysteresis against
//! measurement noise; the sim backend computes through the host
//! kernels, so without the margin every covered call would flip).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::coordinator::CallSiteId;

/// Observations required on *each* route before measured routing may
/// override the device-first default.
pub const MIN_SAMPLES: u64 = 3;

/// Host must be predicted at least this many times faster than the
/// device before a site flips to measured-host routing.
pub const FLIP_MARGIN: f64 = 2.0;

/// EWMA throughput state of one call site.
#[derive(Clone, Copy, Debug, Default)]
pub struct SiteThroughput {
    /// Host flop/s EWMA (0 until the first host observation).
    pub host_flops_s: f64,
    /// Host bytes/s EWMA.
    pub host_bytes_s: f64,
    /// Device flop/s EWMA.
    pub device_flops_s: f64,
    /// Device bytes/s EWMA.
    pub device_bytes_s: f64,
    /// Host observations recorded.
    pub host_samples: u64,
    /// Device observations recorded.
    pub device_samples: u64,
    /// Last `advantageous` verdict (None until routing first consults
    /// the site) — the flip detector's memory.
    last_device: Option<bool>,
}

/// Per-site measured-throughput registry feeding the routing policy.
pub struct ThroughputTracker {
    /// EWMA window (observations); `alpha = 2 / (window + 1)`.
    window: u32,
    inner: Mutex<Inner>,
}

struct Inner {
    sites: HashMap<CallSiteId, SiteThroughput>,
    flips: u64,
}

impl ThroughputTracker {
    /// Empty tracker with the given EWMA window
    /// (`[offload] ewma_window`, clamped to ≥ 1).
    pub fn new(window: u32) -> Self {
        ThroughputTracker {
            window: window.max(1),
            inner: Mutex::new(Inner {
                sites: HashMap::new(),
                flips: 0,
            }),
        }
    }

    /// The configured EWMA window.
    pub fn window(&self) -> u32 {
        self.window
    }

    fn alpha(&self) -> f64 {
        2.0 / (self.window as f64 + 1.0)
    }

    /// Record one observation: `flops` of work (the emulated slice-pair
    /// work, not raw GEMM FLOPs, so predictions stay comparable with
    /// the routing threshold) and `bytes` of operand traffic served in
    /// `secs`, on the device (`device = true`) or the host SIMD path.
    /// Non-positive work or time is ignored (degenerate measurements
    /// would poison the averages).
    pub fn record(&self, site: CallSiteId, device: bool, flops: f64, bytes: f64, secs: f64) {
        if secs <= 0.0 || flops <= 0.0 {
            return;
        }
        let alpha = self.alpha();
        let mut inner = self.inner.lock().unwrap();
        let s = inner.sites.entry(site).or_default();
        let ewma = |old: f64, fresh: f64| {
            if old == 0.0 {
                fresh
            } else {
                alpha * fresh + (1.0 - alpha) * old
            }
        };
        if device {
            s.device_flops_s = ewma(s.device_flops_s, flops / secs);
            s.device_bytes_s = ewma(s.device_bytes_s, bytes / secs);
            s.device_samples += 1;
        } else {
            s.host_flops_s = ewma(s.host_flops_s, flops / secs);
            s.host_bytes_s = ewma(s.host_bytes_s, bytes / secs);
            s.host_samples += 1;
        }
    }

    /// Snapshot one site's EWMA state (None until an observation).
    pub fn snapshot(&self, site: CallSiteId) -> Option<SiteThroughput> {
        self.inner.lock().unwrap().sites.get(site).copied()
    }

    /// Route flips the measured predicate has caused: transitions of a
    /// site's verdict between device-advantageous and host-faster.
    pub fn flips(&self) -> u64 {
        self.inner.lock().unwrap().flips
    }

    /// The routing policy's measured predicate: is the device (still)
    /// the right route for `flops` of work and `bytes` of traffic at
    /// `site`?  `device_prior_secs` is the static-perfmodel estimate,
    /// used until the device has [`MIN_SAMPLES`] of its own.  A host
    /// with no warm measurement answers `true` — the seed behaviour
    /// (device-first) is the cold-start policy.
    pub fn advantageous(
        &self,
        site: CallSiteId,
        flops: f64,
        bytes: f64,
        device_prior_secs: f64,
    ) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let snap = *inner.sites.entry(site).or_default();
        let predict = |flops_s: f64, bytes_s: f64| -> Option<f64> {
            if flops_s <= 0.0 {
                return None;
            }
            // Roofline-style: the route takes as long as its slower of
            // compute and traffic.
            let compute = flops / flops_s;
            let traffic = if bytes_s > 0.0 { bytes / bytes_s } else { 0.0 };
            Some(compute.max(traffic))
        };
        let verdict = match predict(snap.host_flops_s, snap.host_bytes_s) {
            None => true, // cold host: device-first seed behaviour
            Some(_) if snap.host_samples < MIN_SAMPLES => true,
            Some(host_secs) => {
                let device_secs = if snap.device_samples >= MIN_SAMPLES {
                    predict(snap.device_flops_s, snap.device_bytes_s)
                        .unwrap_or(device_prior_secs)
                } else {
                    device_prior_secs
                };
                !(host_secs * FLIP_MARGIN < device_secs)
            }
        };
        if snap.last_device.is_some_and(|prev| prev != verdict) {
            inner.flips += 1;
        }
        if let Some(s) = inner.sites.get_mut(site) {
            s.last_device = Some(verdict);
        }
        verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SITE: CallSiteId = "throughput.rs:test";

    #[test]
    fn cold_sites_stay_device_first() {
        let t = ThroughputTracker::new(16);
        assert!(t.advantageous(SITE, 1e9, 1e6, 1e-3));
        assert_eq!(t.flips(), 0);
        assert!(t.snapshot(SITE).is_some(), "consultation creates the entry");
    }

    #[test]
    fn ewma_warms_up_and_converges() {
        let t = ThroughputTracker::new(3); // alpha = 0.5
        t.record(SITE, false, 100.0, 0.0, 1.0); // 100 flop/s
        assert_eq!(t.snapshot(SITE).unwrap().host_flops_s, 100.0);
        t.record(SITE, false, 300.0, 0.0, 1.0); // EWMA: 0.5*300 + 0.5*100
        let s = t.snapshot(SITE).unwrap();
        assert_eq!(s.host_flops_s, 200.0);
        assert_eq!(s.host_samples, 2);
        // degenerate observations are ignored
        t.record(SITE, false, 0.0, 0.0, 1.0);
        t.record(SITE, false, 100.0, 0.0, 0.0);
        assert_eq!(t.snapshot(SITE).unwrap().host_samples, 2);
    }

    #[test]
    fn warm_fast_host_flips_and_counts_the_transition() {
        let t = ThroughputTracker::new(16);
        // cold consultation: device-first baseline verdict
        assert!(t.advantageous(SITE, 1e9, 8e6, 1.0));
        // warm both routes past MIN_SAMPLES: host 10x device throughput
        for _ in 0..MIN_SAMPLES {
            t.record(SITE, false, 1e9, 8e6, 1e-3); // host: 1e12 flop/s
            t.record(SITE, true, 1e9, 8e6, 1e-2); // device: 1e11 flop/s
        }
        // host predicts 1e-3 s vs device 1e-2 s: the 2x margin is
        // cleared, the site flips host-side, and the flip is counted.
        assert!(!t.advantageous(SITE, 1e9, 8e6, 1.0));
        assert_eq!(t.flips(), 1);
        assert!(!t.advantageous(SITE, 1e9, 8e6, 1.0), "verdict is stable once warm");
        assert_eq!(t.flips(), 1, "a stable verdict is not re-counted");
    }

    #[test]
    fn prior_serves_until_device_is_warm() {
        let t = ThroughputTracker::new(16);
        for _ in 0..MIN_SAMPLES {
            t.record(SITE, false, 1e9, 0.0, 1e-3); // host: 1e12 flop/s
        }
        // device unmeasured: a fast prior keeps the call on the device
        assert!(t.advantageous(SITE, 1e9, 0.0, 1e-4));
        // ... and a slow prior flips it host-side
        assert!(!t.advantageous(SITE, 1e9, 0.0, 1.0));
        assert_eq!(t.flips(), 1, "the verdict transition is counted");
    }

    #[test]
    fn comparable_routes_stay_on_the_device() {
        // The sim backend computes through the host kernels: measured
        // throughput is ~equal, so the 2x margin must keep the call on
        // the device (the seed routing behaviour).
        let t = ThroughputTracker::new(16);
        for _ in 0..MIN_SAMPLES {
            t.record(SITE, false, 1e9, 8e6, 1.00e-3);
            t.record(SITE, true, 1e9, 8e6, 1.05e-3);
        }
        assert!(t.advantageous(SITE, 1e9, 8e6, 1.0));
        assert_eq!(t.flips(), 0);
    }
}
