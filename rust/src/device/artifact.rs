//! Per-bucket batched artifacts and their content-addressed cache.
//!
//! The batch engine's device path executes one *compiled artifact* per
//! engine bucket — the device analogue of the host path's
//! `fused_ozaki_sweep_many`: a single submission that runs every
//! member's retained slice products.  An artifact is identified by what
//! the compiled program depends on — exact bucket shape (device
//! programs are shape-exact, exactly like XLA executables), real vs
//! complex decomposition, split count, and the backend it was compiled
//! for — and carries everything a submission needs that is *derivable
//! at compile time*: the anti-diagonal slice weights and the effective
//! kernel configuration.  Compiling it once per key and serving repeat
//! buckets from the cache is what amortises per-call offload overhead
//! into per-bucket overhead.
//!
//! The cache is bounded ([`crate::resilience::OffloadConfig::
//! artifact_cache`], `[offload] artifact_cache`) with LRU eviction, and
//! publishes hit/miss/eviction counters for the PEAK `device` column
//! and `BENCH_device.json`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::kernels::KernelConfig;
use crate::tune::ShapeClass;

/// Identity of one batched device artifact — everything the compiled
/// program's code depends on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// Bucket rows (exact — compiled programs are shape-exact).
    pub m: usize,
    /// Bucket contraction length.
    pub k: usize,
    /// Bucket columns.
    pub n: usize,
    /// Whether members are complex GEMMs (the 4-real-GEMM
    /// decomposition rides one artifact).
    pub complex: bool,
    /// Emulated split count the program was compiled for.
    pub splits: u32,
    /// Backend label the program targets (`sim` / `pjrt`).
    pub backend: &'static str,
}

impl ArtifactKey {
    /// The power-of-two shape class this key falls in (the panel-cache
    /// style coarse label, used for reporting; the key itself stays
    /// exact for bit safety).
    pub fn class(&self) -> ShapeClass {
        ShapeClass::of(self.m, self.k, self.n)
    }

    /// Human-readable label, e.g. `sim:m6n6k8:d:s6`.
    pub fn label(&self) -> String {
        format!(
            "{}:{}:{}:s{}",
            self.backend,
            self.class().label(),
            if self.complex { "z" } else { "d" },
            self.splits
        )
    }
}

/// One compiled batched artifact: the per-bucket program state shared
/// by every submission with the same [`ArtifactKey`].
#[derive(Clone, Debug)]
pub struct DeviceArtifact {
    /// The identity this artifact was compiled for.
    pub key: ArtifactKey,
    /// Anti-diagonal slice weights (`d < splits` retained), fixed at
    /// compile time.
    pub weights: Vec<f64>,
    /// Effective kernel configuration the submission executes under —
    /// the same one the sequential host path resolves for this shape,
    /// so batched results stay bit-identical by construction.
    pub ecfg: KernelConfig,
    /// Where the blocking constants came from (`default` / `pretuned` /
    /// `cache`) — the PEAK `tuned` column's input.
    pub tuned: &'static str,
}

/// Hit/miss/eviction counters of the artifact cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArtifactCacheStats {
    /// Submissions served by an already-compiled artifact.
    pub hits: u64,
    /// Submissions that had to compile a fresh artifact.
    pub misses: u64,
    /// Artifacts evicted to keep the cache at capacity.
    pub evictions: u64,
}

struct Entry {
    artifact: Arc<DeviceArtifact>,
    last_used: u64,
}

struct Inner {
    map: HashMap<ArtifactKey, Entry>,
    /// Monotonic use counter — the LRU clock (deterministic, unlike
    /// wall time, and immune to equal-timestamp ties).
    tick: u64,
    stats: ArtifactCacheStats,
}

/// Bounded, content-addressed cache of compiled batched artifacts with
/// LRU eviction.
pub struct ArtifactCache {
    inner: Mutex<Inner>,
    capacity: usize,
}

impl ArtifactCache {
    /// Empty cache holding at most `capacity` artifacts (clamped to
    /// ≥ 1 so a misconfigured zero can never wedge compilation).
    pub fn new(capacity: usize) -> Self {
        ArtifactCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
                stats: ArtifactCacheStats::default(),
            }),
            capacity: capacity.max(1),
        }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch the artifact for `key`, compiling it with `compile` on a
    /// miss (evicting the least-recently-used entry when full).
    /// Returns the artifact and whether it was a cache hit.
    pub fn get_or_compile(
        &self,
        key: ArtifactKey,
        compile: impl FnOnce() -> DeviceArtifact,
    ) -> (Arc<DeviceArtifact>, bool) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            e.last_used = tick;
            inner.stats.hits += 1;
            return (e.artifact.clone(), true);
        }
        inner.stats.misses += 1;
        let artifact = Arc::new(compile());
        if inner.map.len() >= self.capacity {
            if let Some(&evict) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k)
            {
                inner.map.remove(&evict);
                inner.stats.evictions += 1;
            }
        }
        inner.map.insert(
            key,
            Entry {
                artifact: artifact.clone(),
                last_used: tick,
            },
        );
        (artifact, false)
    }

    /// Counters snapshot.
    pub fn stats(&self) -> ArtifactCacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Artifacts currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether nothing has been compiled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: usize, splits: u32) -> ArtifactKey {
        ArtifactKey {
            m,
            k: 64,
            n: 64,
            complex: false,
            splits,
            backend: "sim",
        }
    }

    fn artifact(k: ArtifactKey) -> DeviceArtifact {
        DeviceArtifact {
            key: k,
            weights: vec![1.0; k.splits as usize],
            ecfg: KernelConfig::default(),
            tuned: "default",
        }
    }

    #[test]
    fn hits_misses_and_identity() {
        let c = ArtifactCache::new(8);
        assert!(c.is_empty());
        let (a1, hit1) = c.get_or_compile(key(64, 6), || artifact(key(64, 6)));
        let (a2, hit2) = c.get_or_compile(key(64, 6), || panic!("must not recompile"));
        assert!(!hit1 && hit2);
        assert!(Arc::ptr_eq(&a1, &a2), "hits serve the same compiled artifact");
        assert_eq!(c.stats(), ArtifactCacheStats { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(c.len(), 1);
        // a different split count is a different program
        let (_, hit3) = c.get_or_compile(key(64, 7), || artifact(key(64, 7)));
        assert!(!hit3);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn lru_evicts_the_coldest_entry() {
        let c = ArtifactCache::new(2);
        c.get_or_compile(key(32, 6), || artifact(key(32, 6)));
        c.get_or_compile(key(64, 6), || artifact(key(64, 6)));
        // touch 32 so 64 is now the LRU entry
        c.get_or_compile(key(32, 6), || panic!("hit expected"));
        c.get_or_compile(key(128, 6), || artifact(key(128, 6)));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        // 32 survived, 64 was evicted and recompiles
        c.get_or_compile(key(32, 6), || panic!("survivor must still hit"));
        let (_, hit) = c.get_or_compile(key(64, 6), || artifact(key(64, 6)));
        assert!(!hit, "evicted artifact recompiles");
    }

    #[test]
    fn zero_capacity_is_clamped_and_labels_render() {
        let c = ArtifactCache::new(0);
        assert_eq!(c.capacity(), 1);
        let k = ArtifactKey {
            m: 100,
            k: 256,
            n: 64,
            complex: true,
            splits: 6,
            backend: "sim",
        };
        assert_eq!(k.label(), format!("sim:{}:z:s6", k.class().label()));
    }
}
