//! Persistent worker pool for the host kernel core.
//!
//! PR 1's band drivers spawned fresh `std::thread::scope` threads on
//! every GEMM; on the many-small-GEMM workloads the paper's LU trailing
//! updates produce, spawn/join cost was the dominant Amdahl term after
//! the fused sweep.  This pool spawns its workers **once per process**
//! (lazily, on the first parallel call) and reuses them for every band
//! and pack task afterwards.
//!
//! Sizing: the pool grows on demand to the largest `threads` any caller
//! requests (i.e. `OZACCEL_THREADS` / `run.threads` via
//! [`crate::kernels::KernelConfig`]), capped at [`MAX_POOL_THREADS`].
//! The calling thread always participates, so a request for `t` threads
//! needs only `t - 1` workers.
//!
//! Work items are *borrowed* closures: [`run`] type-erases the closure
//! behind a raw pointer and blocks on a completion latch until every
//! job has finished, so the borrow never outlives the call.  Nested
//! [`run`] calls from inside a pool task execute inline — the pool
//! never blocks a worker on another task's completion, which keeps it
//! deadlock-free by construction.
//!
//! Determinism: the pool only decides *who* executes a job, never what
//! the job computes or where it writes.  Band partitioning (and
//! therefore every kernel result bit) depends only on the caller's
//! requested `threads`, exactly as with the scoped-thread code it
//! replaces.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, OnceLock};

/// Hard cap on pool workers (a safety bound, far above any sane
/// `OZACCEL_THREADS`).
pub const MAX_POOL_THREADS: usize = 512;

/// A type-erased, borrowed work item.  `ctx` points at the submitting
/// call's closure and `latch` at its completion latch; both live on the
/// submitter's stack and are kept alive because [`run`] does not return
/// until the latch reports every job done.
struct Task {
    call: unsafe fn(*const (), usize),
    ctx: *const (),
    index: usize,
    latch: *const Latch,
}

// Safety: Task's raw pointers reference the submitting thread's stack
// frame, which outlives all uses — `run` blocks until the latch counts
// every job complete before that frame unwinds.  The pointed-to closure
// is `Sync`, so shared execution from worker threads is sound.
unsafe impl Send for Task {}

struct LatchState {
    done: usize,
    total: usize,
    panicked: bool,
}

/// Counts completed jobs of one `run` call.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

impl Latch {
    fn new(total: usize) -> Self {
        Latch {
            state: Mutex::new(LatchState {
                done: 0,
                total,
                panicked: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.done += 1;
        s.panicked |= panicked;
        if s.done >= s.total {
            self.cv.notify_all();
        }
    }

    /// Block until all jobs completed; returns whether any panicked.
    fn wait_done(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.done < s.total {
            s = self.cv.wait(s).unwrap();
        }
        s.panicked
    }

    fn is_done(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.done >= s.total
    }
}

struct Pool {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    spawned: Mutex<usize>,
}

impl Pool {
    fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            spawned: Mutex::new(0),
        })
    }

    /// Grow the worker set to at least `want` detached workers.
    fn ensure_workers(&'static self, want: usize) {
        let want = want.min(MAX_POOL_THREADS - 1);
        let mut n = self.spawned.lock().unwrap();
        while *n < want {
            let id = *n;
            std::thread::Builder::new()
                .name(format!("ozaccel-pool-{id}"))
                .spawn(move || worker_loop(Pool::global()))
                .expect("spawn pool worker");
            *n += 1;
        }
    }
}

thread_local! {
    /// Set while this thread is executing a pool task; nested `run`
    /// calls observe it and fall back to inline execution.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let task = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = pool.cv.wait(q).unwrap();
            }
        };
        exec_task(task);
    }
}

/// Execute one task, completing its latch even if the closure panics
/// (the panic is surfaced to the submitter, and the worker survives).
fn exec_task(t: Task) {
    IN_POOL.with(|f| f.set(true));
    let result = catch_unwind(AssertUnwindSafe(|| unsafe { (t.call)(t.ctx, t.index) }));
    IN_POOL.with(|f| f.set(false));
    // Safety: the submitter keeps the latch alive until it has observed
    // done == total, which can only happen after this call returns.
    unsafe { (*t.latch).complete(result.is_err()) };
}

/// Number of persistent workers spawned so far (tests/introspection).
pub fn workers_spawned() -> usize {
    *Pool::global().spawned.lock().unwrap()
}

/// Execute `jobs` indexed work items (`f(0) .. f(jobs-1)`) with up to
/// `threads` concurrent executors — the calling thread plus persistent
/// pool workers — and block until all have completed.
///
/// Falls back to inline sequential execution when `threads <= 1`, when
/// only one job exists, or when called from inside a pool task (nested
/// parallelism runs inline; the pool stays deadlock-free).  Panics in
/// any job are re-raised here after all jobs have settled.
pub fn run<F: Fn(usize) + Sync>(jobs: usize, threads: usize, f: F) {
    if jobs == 0 {
        return;
    }
    let threads = threads.min(jobs).min(MAX_POOL_THREADS);
    if threads <= 1 || jobs == 1 || IN_POOL.with(|x| x.get()) {
        for i in 0..jobs {
            f(i);
        }
        return;
    }

    unsafe fn call_closure<F: Fn(usize) + Sync>(ctx: *const (), index: usize) {
        (*(ctx as *const F))(index);
    }

    let pool = Pool::global();
    pool.ensure_workers(threads - 1);
    let latch = Latch::new(jobs);
    {
        let mut q = pool.queue.lock().unwrap();
        for index in 0..jobs {
            q.push_back(Task {
                call: call_closure::<F>,
                ctx: &f as *const F as *const (),
                index,
                latch: &latch as *const Latch,
            });
        }
    }
    pool.cv.notify_all();

    // The caller helps drain the queue (its own jobs, or — harmlessly —
    // another concurrent run's) until its own latch completes or the
    // queue runs dry, then waits for in-flight stragglers.  The latch
    // check bounds the help: once this run's jobs are done the caller
    // returns promptly instead of servicing other runs' backlogs.
    while !latch.is_done() {
        let task = pool.queue.lock().unwrap().pop_front();
        match task {
            Some(t) => exec_task(t),
            None => break,
        }
    }
    if latch.wait_done() {
        panic!("worker pool: a parallel task panicked");
    }
}

/// A raw mutable pointer blessed for cross-thread use.  The band and
/// pack drivers use it to hand **disjoint** regions of one output
/// buffer to pool tasks; safety rests entirely on the caller's index
/// partition being disjoint and in-bounds.
#[derive(Clone, Copy, Debug)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// The wrapped raw pointer.
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_exactly_once() {
        for jobs in [0usize, 1, 2, 7, 64] {
            for threads in [1usize, 2, 4, 9] {
                let hits: Vec<AtomicUsize> = (0..jobs).map(|_| AtomicUsize::new(0)).collect();
                run(jobs, threads, |i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                    "jobs={jobs} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn disjoint_writes_through_send_ptr() {
        let mut out = vec![0usize; 1000];
        let base = SendPtr(out.as_mut_ptr());
        run(10, 4, |j| {
            let chunk = unsafe { std::slice::from_raw_parts_mut(base.get().add(j * 100), 100) };
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = j * 100 + i;
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i));
    }

    #[test]
    fn nested_runs_execute_inline() {
        let count = AtomicUsize::new(0);
        run(4, 4, |_| {
            // inner run must not deadlock even with every worker busy
            run(3, 4, |_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn pool_survives_repeated_use() {
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            run(8, 3, |i| {
                sum.fetch_add(i + 1, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), 36, "round {round}");
        }
        assert!(workers_spawned() >= 2);
        assert!(workers_spawned() <= MAX_POOL_THREADS);
    }

    #[test]
    fn task_panic_propagates_and_pool_recovers() {
        let caught = std::panic::catch_unwind(|| {
            run(4, 2, |i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        });
        assert!(caught.is_err(), "panic must surface to the submitter");
        // The pool must still work afterwards.
        let sum = AtomicUsize::new(0);
        run(6, 3, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 15);
    }
}
