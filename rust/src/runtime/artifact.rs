//! Artifact manifest: which (kind, splits, shape) modules exist on disk.
//!
//! `artifacts/manifest.txt` is plain text (`kind splits M K N filename`)
//! written by `python/compile/aot.py`; a hand parser keeps the runtime
//! free of serde (unavailable offline).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::ozaki::ComputeMode;

/// Kind of compiled GEMM module.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// Native FP64 `dot` (the paper's `dgemm` mode).
    Dgemm,
    /// Ozaki INT8 emulation with a given split count.
    Ozdg { splits: u32 },
}

impl ArtifactKind {
    /// Artifact kind serving a compute mode.
    pub fn for_mode(mode: ComputeMode) -> Self {
        match mode {
            ComputeMode::Dgemm => ArtifactKind::Dgemm,
            ComputeMode::Int8 { splits } => ArtifactKind::Ozdg { splits },
        }
    }
}

/// One manifest entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    /// Compute mode the artifact was lowered for.
    pub kind: ArtifactKind,
    /// Rows of A the artifact was shaped for.
    pub m: usize,
    /// Contraction depth the artifact was shaped for.
    pub k: usize,
    /// Columns of B the artifact was shaped for.
    pub n: usize,
    /// HLO text file, relative to the artifact directory.
    pub path: PathBuf,
}

/// Parsed manifest with bucket lookup.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// kind -> sorted list of (m, k, n, path).
    by_kind: BTreeMap<ArtifactKind, Vec<Artifact>>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} ({e}); run `make artifacts`",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` prefixes the filenames.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut by_kind: BTreeMap<ArtifactKind, Vec<Artifact>> = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 6 {
                return Err(Error::Manifest(format!(
                    "line {}: expected 6 fields, got {}",
                    lineno + 1,
                    f.len()
                )));
            }
            let splits: u32 = f[1]
                .parse()
                .map_err(|_| Error::Manifest(format!("line {}: bad splits", lineno + 1)))?;
            let kind = match f[0] {
                "dgemm" => ArtifactKind::Dgemm,
                "ozdg" => ArtifactKind::Ozdg { splits },
                other => {
                    return Err(Error::Manifest(format!(
                        "line {}: unknown kind {other:?}",
                        lineno + 1
                    )))
                }
            };
            let dims: Vec<usize> = f[2..5]
                .iter()
                .map(|s| s.parse())
                .collect::<std::result::Result<_, _>>()
                .map_err(|_| Error::Manifest(format!("line {}: bad dims", lineno + 1)))?;
            by_kind.entry(kind).or_default().push(Artifact {
                kind,
                m: dims[0],
                k: dims[1],
                n: dims[2],
                path: dir.join(f[5]),
            });
        }
        for list in by_kind.values_mut() {
            // sort by padded volume so `find_bucket` picks the cheapest cover
            list.sort_by_key(|a| a.m * a.k * a.n);
        }
        Ok(Manifest { by_kind })
    }

    /// Exact-shape lookup.
    pub fn find_exact(&self, kind: ArtifactKind, m: usize, k: usize, n: usize) -> Option<&Artifact> {
        self.by_kind
            .get(&kind)?
            .iter()
            .find(|a| a.m == m && a.k == k && a.n == n)
    }

    /// Smallest artifact whose shape covers (m, k, n) — zero padding is
    /// exact for GEMM, so any covering bucket computes the right answer.
    pub fn find_bucket(&self, kind: ArtifactKind, m: usize, k: usize, n: usize) -> Option<&Artifact> {
        self.by_kind
            .get(&kind)?
            .iter()
            .find(|a| a.m >= m && a.k >= k && a.n >= n)
    }

    /// All artifacts of a kind (sorted by volume).
    pub fn of_kind(&self, kind: ArtifactKind) -> &[Artifact] {
        self.by_kind.get(&kind).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Total number of artifacts.
    pub fn len(&self) -> usize {
        self.by_kind.values().map(|v| v.len()).sum()
    }

    /// Whether the manifest lists no artifacts at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distinct split counts with at least one artifact.
    pub fn available_splits(&self) -> Vec<u32> {
        self.by_kind
            .keys()
            .filter_map(|k| match k {
                ArtifactKind::Ozdg { splits } => Some(*splits),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# kind splits M K N filename
dgemm 0 64 64 64 dgemm_64x64x64.hlo.txt
ozdg 3 64 64 64 ozdg_s3_64x64x64.hlo.txt
ozdg 3 256 64 256 ozdg_s3_256x64x256.hlo.txt
ozdg 6 128 64 128 ozdg_s6_128x64x128.hlo.txt
";

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/art")).unwrap()
    }

    #[test]
    fn parses_and_counts() {
        let m = manifest();
        assert_eq!(m.len(), 4);
        assert_eq!(m.available_splits(), vec![3, 6]);
    }

    #[test]
    fn exact_lookup() {
        let m = manifest();
        let a = m
            .find_exact(ArtifactKind::Ozdg { splits: 3 }, 64, 64, 64)
            .unwrap();
        assert_eq!(a.path, PathBuf::from("/art/ozdg_s3_64x64x64.hlo.txt"));
        assert!(m.find_exact(ArtifactKind::Ozdg { splits: 4 }, 64, 64, 64).is_none());
    }

    #[test]
    fn bucket_picks_smallest_cover() {
        let m = manifest();
        let a = m
            .find_bucket(ArtifactKind::Ozdg { splits: 3 }, 65, 10, 65)
            .unwrap();
        assert_eq!((a.m, a.k, a.n), (256, 64, 256));
        // too large for any bucket
        assert!(m.find_bucket(ArtifactKind::Ozdg { splits: 3 }, 300, 64, 64).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("dgemm 0 64 64", Path::new("/a")).is_err());
        assert!(Manifest::parse("wat 0 1 1 1 f", Path::new("/a")).is_err());
        assert!(Manifest::parse("ozdg x 1 1 1 f", Path::new("/a")).is_err());
        assert!(Manifest::parse("ozdg 3 a 1 1 f", Path::new("/a")).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = Manifest::parse("# hi\n\n  \n", Path::new("/a")).unwrap();
        assert!(m.is_empty());
    }
}
