//! One compiled GEMM executable: literal marshalling + execution.

use std::path::Path;

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// A compiled `(A: f64[M,K], B: f64[K,N]) -> (C: f64[M,N],)` module.
pub struct GemmExecutable {
    exe: xla::PjRtLoadedExecutable,
    m: usize,
    k: usize,
    n: usize,
}

impl GemmExecutable {
    /// Load HLO text, compile on `client`.
    pub fn load(client: &xla::PjRtClient, path: &Path, m: usize, k: usize, n: usize) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(GemmExecutable { exe, m, k, n })
    }

    /// Compiled shape.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.m, self.k, self.n)
    }

    /// Execute on exact-shape inputs.
    pub fn run(&self, a: &Mat<f64>, b: &Mat<f64>) -> Result<Mat<f64>> {
        if a.rows() != self.m || a.cols() != self.k || b.rows() != self.k || b.cols() != self.n {
            return Err(Error::Shape(format!(
                "executable {}x{}x{} fed {}x{} @ {}x{}",
                self.m,
                self.k,
                self.n,
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            )));
        }
        let la = literal_f64(a)?;
        let lb = literal_f64(b)?;
        let results = self.exe.execute::<xla::Literal>(&[la, lb])?;
        let out = results[0][0].to_literal_sync()?;
        // the model lowers with return_tuple=True -> unwrap the 1-tuple
        let c = out.to_tuple1()?;
        let mut buf = vec![0.0f64; self.m * self.n];
        c.copy_raw_to(&mut buf)?;
        Mat::from_vec(self.m, self.n, buf)
    }

    /// Execute with zero padding up to the compiled bucket, slicing the
    /// result back to `(m_logical, n_logical)`.  Zero padding is exact
    /// for GEMM, so this returns the same values as an exact-shape run.
    pub fn run_padded(
        &self,
        a: &Mat<f64>,
        b: &Mat<f64>,
        m_logical: usize,
        n_logical: usize,
    ) -> Result<Mat<f64>> {
        let ap;
        let bp;
        let a = if a.rows() == self.m && a.cols() == self.k {
            a
        } else {
            ap = a.padded(self.m, self.k);
            &ap
        };
        let b = if b.rows() == self.k && b.cols() == self.n {
            b
        } else {
            bp = b.padded(self.k, self.n);
            &bp
        };
        let full = self.run(a, b)?;
        if m_logical == self.m && n_logical == self.n {
            Ok(full)
        } else {
            Ok(full.block(0, 0, m_logical, n_logical))
        }
    }
}

/// Row-major `Mat<f64>` → XLA literal without an element-wise copy.
fn literal_f64(m: &Mat<f64>) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(m.data().as_ptr() as *const u8, m.data().len() * 8)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F64,
        &[m.rows(), m.cols()],
        bytes,
    )?)
}
