//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! The Rust request path never touches Python: `make artifacts` lowered
//! the L2 JAX model (containing the L1 Pallas kernel) to HLO text, and
//! this module compiles those modules on the PJRT CPU client — lazily,
//! once per shape — and runs them.
//!
//! Wiring follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`,
//! with `to_tuple1` unwrapping (the model lowers with
//! `return_tuple=True`).
//!
//! This module also owns [`pool`], the process-wide persistent worker
//! pool the host kernel core runs its band and pack tasks on.

mod artifact;
mod cache;
mod exec;
pub mod pool;

pub use artifact::{Artifact, ArtifactKind, Manifest};
pub use cache::Runtime;
pub use exec::GemmExecutable;

/// Default artifact directory, overridable via `OZACCEL_ARTIFACTS`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("OZACCEL_ARTIFACTS") {
        return dir.into();
    }
    // Walk up from the current dir so tests/benches/examples all find
    // the repo-root artifacts/ regardless of their working directory.
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = d.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !d.pop() {
            return "artifacts".into();
        }
    }
}
