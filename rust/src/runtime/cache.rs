//! Lazy executable cache: one PJRT client, one compiled executable per
//! (kind, shape), compiled on first use and reused for the rest of the
//! run (DESIGN.md §Perf: compile once per shape).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use log::{debug, info};

use super::artifact::{ArtifactKind, Manifest};
use super::exec::GemmExecutable;
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// PJRT runtime with the artifact manifest and executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<(ArtifactKind, usize, usize, usize), &'static GemmExecutable>>,
    stats: Mutex<RuntimeStats>,
}

/// Counters for the §Perf analysis.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    /// HLO artifacts compiled (first execution per shape bucket).
    pub compiles: u64,
    /// Total executable invocations.
    pub executions: u64,
    /// Executions that padded operands up to a larger bucket.
    pub padded_executions: u64,
}

impl Runtime {
    /// Create against an artifact directory (must contain manifest.txt).
    pub fn new(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        info!(
            "runtime: PJRT {} with {} devices, {} artifacts from {}",
            client.platform_name(),
            client.device_count(),
            manifest.len(),
            dir.display()
        );
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Create against the default artifact dir (env/repo discovery).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(super::default_artifact_dir())
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact directory in use.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Runtime counters snapshot.
    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().unwrap()
    }

    /// True if a bucket exists for this GEMM under `kind`.
    pub fn covers(&self, kind: ArtifactKind, m: usize, k: usize, n: usize) -> bool {
        self.manifest.find_bucket(kind, m, k, n).is_some()
    }

    /// Compile-or-fetch the executable for the smallest covering bucket.
    fn executable(
        &self,
        kind: ArtifactKind,
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<&'static GemmExecutable> {
        let art = self
            .manifest
            .find_bucket(kind, m, k, n)
            .ok_or(Error::NoArtifact {
                kind: match kind {
                    ArtifactKind::Dgemm => "dgemm",
                    ArtifactKind::Ozdg { .. } => "ozdg",
                },
                splits: match kind {
                    ArtifactKind::Ozdg { splits } => splits,
                    _ => 0,
                },
                m,
                k,
                n,
            })?
            .clone();
        let key = (kind, art.m, art.k, art.n);
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&key) {
            return Ok(exe);
        }
        debug!(
            "runtime: compiling {:?} {}x{}x{} from {}",
            kind,
            art.m,
            art.k,
            art.n,
            art.path.display()
        );
        let exe = GemmExecutable::load(&self.client, &art.path, art.m, art.k, art.n)?;
        self.stats.lock().unwrap().compiles += 1;
        // Executables live for the process lifetime; leaking them gives a
        // 'static borrow without self-referential lifetimes.
        let leaked: &'static GemmExecutable = Box::leak(Box::new(exe));
        cache.insert(key, leaked);
        Ok(leaked)
    }

    /// Run an FP64 GEMM through the artifact for `kind`, padding to the
    /// bucket when the logical shape is smaller.
    pub fn gemm(&self, kind: ArtifactKind, a: &Mat<f64>, b: &Mat<f64>) -> Result<Mat<f64>> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        if a.cols() != b.rows() {
            return Err(Error::Shape(format!(
                "runtime gemm: {}x{} @ {}x{}",
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            )));
        }
        let exe = self.executable(kind, m, k, n)?;
        {
            let mut s = self.stats.lock().unwrap();
            s.executions += 1;
            if exe.shape() != (m, k, n) {
                s.padded_executions += 1;
            }
        }
        exe.run_padded(a, b, m, n)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs where
    // they can assume `make artifacts` has run; here we only check the
    // error path that needs no artifacts.
    #[test]
    fn missing_manifest_is_a_clean_error() {
        match Runtime::new(PathBuf::from("/nonexistent-dir-xyz")) {
            Err(Error::Manifest(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("unexpected error {other:?}"),
            Ok(_) => panic!("expected an error"),
        }
    }
}
