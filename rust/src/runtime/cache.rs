//! Lazy executable cache: one PJRT client, one compiled executable per
//! (kind, shape), compiled on first use and reused for the rest of the
//! run (DESIGN.md §Perf: compile once per shape).
//!
//! Besides the PJRT backend there is a **simulated** device
//! ([`Runtime::simulated`], selected by `[offload] backend = "sim"`):
//! it covers every shape and computes through the host kernels, so the
//! whole offload seam — routing, retry, circuit breaker, fallback —
//! runs end-to-end on machines with no PJRT client or compiled
//! artifacts.  A sim "device" result is bit-identical to the host path
//! by construction, which is exactly the invariant the resilience
//! layer's fallback tests pin.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use log::{debug, info};

use super::artifact::{ArtifactKind, Manifest};
use super::exec::GemmExecutable;
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Which device actually executes [`Runtime::gemm`].
enum Backend {
    /// PJRT client over compiled HLO artifacts.
    Pjrt {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<(ArtifactKind, usize, usize, usize), &'static GemmExecutable>>,
    },
    /// In-process simulated device (host-kernel compute, full coverage).
    Sim,
}

/// Device runtime with the artifact manifest and executable cache.
pub struct Runtime {
    backend: Backend,
    manifest: Manifest,
    dir: PathBuf,
    stats: Mutex<RuntimeStats>,
}

/// Counters for the §Perf analysis.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    /// HLO artifacts compiled (first execution per shape bucket).
    pub compiles: u64,
    /// Total executable invocations.
    pub executions: u64,
    /// Executions that padded operands up to a larger bucket.
    pub padded_executions: u64,
}

impl Runtime {
    /// Create against an artifact directory (must contain manifest.txt).
    pub fn new(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        info!(
            "runtime: PJRT {} with {} devices, {} artifacts from {}",
            client.platform_name(),
            client.device_count(),
            manifest.len(),
            dir.display()
        );
        Ok(Runtime {
            backend: Backend::Pjrt {
                client,
                cache: Mutex::new(HashMap::new()),
            },
            manifest,
            dir,
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Create against the default artifact dir (env/repo discovery).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(super::default_artifact_dir())
    }

    /// Create the simulated device: no client, no artifacts, every
    /// shape covered, results computed by the host kernels (so they are
    /// bit-identical to host-routed calls by construction).
    pub fn simulated() -> Self {
        info!("runtime: simulated device backend (host-kernel compute, full coverage)");
        Runtime {
            backend: Backend::Sim,
            manifest: Manifest::default(),
            dir: PathBuf::new(),
            stats: Mutex::new(RuntimeStats::default()),
        }
    }

    /// The artifact manifest (empty for the simulated backend).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact directory in use (empty for the simulated backend).
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Short backend label (`pjrt` / `sim`) for reports and logs.
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Sim => "sim",
        }
    }

    /// Runtime counters snapshot.
    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().unwrap()
    }

    /// True if a bucket exists for this GEMM under `kind` (always, for
    /// the simulated backend).
    pub fn covers(&self, kind: ArtifactKind, m: usize, k: usize, n: usize) -> bool {
        match self.backend {
            Backend::Pjrt { .. } => self.manifest.find_bucket(kind, m, k, n).is_some(),
            Backend::Sim => true,
        }
    }

    /// Compile-or-fetch the executable for the smallest covering bucket.
    fn executable(
        &self,
        client: &xla::PjRtClient,
        cache: &Mutex<HashMap<(ArtifactKind, usize, usize, usize), &'static GemmExecutable>>,
        kind: ArtifactKind,
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<&'static GemmExecutable> {
        let art = self
            .manifest
            .find_bucket(kind, m, k, n)
            .ok_or(Error::NoArtifact {
                kind: match kind {
                    ArtifactKind::Dgemm => "dgemm",
                    ArtifactKind::Ozdg { .. } => "ozdg",
                },
                splits: match kind {
                    ArtifactKind::Ozdg { splits } => splits,
                    _ => 0,
                },
                m,
                k,
                n,
            })?
            .clone();
        let key = (kind, art.m, art.k, art.n);
        let mut cache = cache.lock().unwrap();
        if let Some(exe) = cache.get(&key) {
            return Ok(exe);
        }
        debug!(
            "runtime: compiling {:?} {}x{}x{} from {}",
            kind,
            art.m,
            art.k,
            art.n,
            art.path.display()
        );
        let exe = GemmExecutable::load(client, &art.path, art.m, art.k, art.n)?;
        self.stats.lock().unwrap().compiles += 1;
        // Executables live for the process lifetime; leaking them gives a
        // 'static borrow without self-referential lifetimes.
        let leaked: &'static GemmExecutable = Box::leak(Box::new(exe));
        cache.insert(key, leaked);
        Ok(leaked)
    }

    /// Run an FP64 GEMM through the artifact for `kind`, padding to the
    /// bucket when the logical shape is smaller.
    pub fn gemm(&self, kind: ArtifactKind, a: &Mat<f64>, b: &Mat<f64>) -> Result<Mat<f64>> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        if a.cols() != b.rows() {
            return Err(Error::Shape(format!(
                "runtime gemm: {}x{} @ {}x{}",
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            )));
        }
        match &self.backend {
            Backend::Pjrt { client, cache } => {
                let exe = self.executable(client, cache, kind, m, k, n)?;
                {
                    let mut s = self.stats.lock().unwrap();
                    s.executions += 1;
                    if exe.shape() != (m, k, n) {
                        s.padded_executions += 1;
                    }
                }
                exe.run_padded(a, b, m, n)
            }
            Backend::Sim => {
                self.stats.lock().unwrap().executions += 1;
                match kind {
                    ArtifactKind::Dgemm => crate::linalg::dgemm(a, b),
                    ArtifactKind::Ozdg { splits } => crate::ozaki::ozaki_dgemm(a, b, splits),
                }
            }
        }
    }

    /// Execute one **batched bucket submission**: every member's
    /// retained slice products in a single device execution, fed with
    /// already-staged panels.  This is the device analogue of the batch
    /// engine's fused host sweep — one `executions` tick covers the
    /// whole bucket, which is exactly the per-call→per-bucket overhead
    /// amortization the device pipeline exists for.  On the simulated
    /// backend the submission computes through the host fused sweep,
    /// so batched device results are bit-identical to the sequential
    /// host path by construction; the PJRT backend's artifacts are
    /// per-call GEMM programs, so it reports a typed
    /// [`Error::Unimplemented`] and callers fall back per-call.
    pub fn batched_sweep(
        &self,
        specs: &[crate::kernels::SweepSpec<'_>],
        ecfg: &crate::kernels::KernelConfig,
    ) -> Result<Vec<Result<Mat<f64>>>> {
        match &self.backend {
            Backend::Pjrt { .. } => Err(Error::Unimplemented(
                "batched bucket submission requires the simulated backend \
                 (PJRT artifacts are per-call)"
                    .into(),
            )),
            Backend::Sim => {
                self.stats.lock().unwrap().executions += 1;
                crate::kernels::fused_ozaki_sweep_many_isolated(specs, ecfg)
            }
        }
    }

    /// Number of compiled executables currently cached (0 for sim).
    pub fn cached_executables(&self) -> usize {
        match &self.backend {
            Backend::Pjrt { cache, .. } => cache.lock().unwrap().len(),
            Backend::Sim => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs where
    // they can assume `make artifacts` has run; here we only check the
    // error path that needs no artifacts.
    #[test]
    fn missing_manifest_is_a_clean_error() {
        match Runtime::new(PathBuf::from("/nonexistent-dir-xyz")) {
            Err(Error::Manifest(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("unexpected error {other:?}"),
            Ok(_) => panic!("expected an error"),
        }
    }

    #[test]
    fn simulated_backend_covers_everything_and_computes_host_bits() {
        let rt = Runtime::simulated();
        assert_eq!(rt.backend_name(), "sim");
        assert!(rt.covers(ArtifactKind::Dgemm, 7, 9, 11));
        assert!(rt.covers(ArtifactKind::Ozdg { splits: 5 }, 4096, 4096, 4096));
        assert_eq!(rt.cached_executables(), 0);

        let mut rng = crate::testing::Rng::new(0x51A1);
        let a = Mat::from_fn(6, 5, |_, _| rng.normal());
        let b = Mat::from_fn(5, 4, |_, _| rng.normal());
        let got = rt.gemm(ArtifactKind::Dgemm, &a, &b).unwrap();
        let want = crate::linalg::dgemm(&a, &b).unwrap();
        assert_eq!(got.data(), want.data(), "sim dgemm is the host dgemm");
        let got = rt.gemm(ArtifactKind::Ozdg { splits: 4 }, &a, &b).unwrap();
        let want = crate::ozaki::ozaki_dgemm(&a, &b, 4).unwrap();
        assert_eq!(got.data(), want.data(), "sim ozdg is the host emulation");
        assert_eq!(rt.stats().executions, 2);
        assert_eq!(rt.stats().compiles, 0);

        // Shape errors still surface uniformly.
        let bad = Mat::from_fn(3, 3, |_, _| 0.0);
        assert!(rt.gemm(ArtifactKind::Dgemm, &a, &bad).is_err());
    }
}
