//! Double-precision complex arithmetic built from scratch.
//!
//! `num-complex` is not available in this offline environment (DESIGN.md
//! §Substitutions), and MuST-mini's multiple-scattering theory is complex
//! end to end, so the crate carries its own `c64`.  The layout is
//! `repr(C)` `(re, im)` so a `&[c64]` can be reinterpreted as interleaved
//! `&[f64]` when marshalling to the runtime.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex number with `f64` components.
#[allow(non_camel_case_types)]
#[derive(Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct c64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

/// Shorthand constructor.
#[inline]
pub const fn c64(re: f64, im: f64) -> c64 {
    c64 { re, im }
}

impl c64 {
    /// Additive identity.
    pub const ZERO: c64 = c64(0.0, 0.0);
    /// Multiplicative identity.
    pub const ONE: c64 = c64(1.0, 0.0);
    /// The imaginary unit.
    pub const I: c64 = c64(0.0, 1.0);

    /// Construct from components (same as the [`c64`] fn shorthand).
    #[inline]
    pub const fn new(re: f64, im: f64) -> Self {
        c64 { re, im }
    }

    /// Purely real value.
    #[inline]
    pub const fn real(re: f64) -> Self {
        c64 { re, im: 0.0 }
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        c64(self.re, -self.im)
    }

    /// Squared magnitude |z|^2 (no sqrt).
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude |z|, overflow-safe via `hypot`.
    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// Argument in (-pi, pi].
    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    /// Multiplicative inverse, overflow-safe (Smith's algorithm).
    pub fn inv(self) -> Self {
        let (a, b) = (self.re, self.im);
        if a.abs() >= b.abs() {
            let r = b / a;
            let d = a + b * r;
            c64(1.0 / d, -r / d)
        } else {
            let r = a / b;
            let d = a * r + b;
            c64(r / d, -1.0 / d)
        }
    }

    /// Principal square root (branch cut along the negative real axis).
    pub fn sqrt(self) -> Self {
        if self.re == 0.0 && self.im == 0.0 {
            return c64::ZERO;
        }
        let m = self.abs();
        let re = ((m + self.re) / 2.0).sqrt();
        let im_mag = ((m - self.re) / 2.0).sqrt();
        c64(re, if self.im >= 0.0 { im_mag } else { -im_mag })
    }

    /// Complex exponential.
    pub fn exp(self) -> Self {
        let r = self.re.exp();
        c64(r * self.im.cos(), r * self.im.sin())
    }

    /// Principal natural logarithm.
    pub fn ln(self) -> Self {
        c64(self.abs().ln(), self.arg())
    }

    /// Complex power z^w = exp(w ln z).
    pub fn powc(self, w: c64) -> Self {
        (self.ln() * w).exp()
    }

    /// Integer power by repeated squaring (exact op-count, no ln branch
    /// issues for negative reals).
    pub fn powi(self, mut n: i32) -> Self {
        if n == 0 {
            return c64::ONE;
        }
        let invert = n < 0;
        if invert {
            n = -n;
        }
        let mut base = self;
        let mut acc = c64::ONE;
        let mut e = n as u32;
        while e > 0 {
            if e & 1 == 1 {
                acc *= base;
            }
            base *= base;
            e >>= 1;
        }
        if invert {
            acc.inv()
        } else {
            acc
        }
    }

    /// Complex sine.
    pub fn sin(self) -> Self {
        c64(
            self.re.sin() * self.im.cosh(),
            self.re.cos() * self.im.sinh(),
        )
    }

    /// Complex cosine.
    pub fn cos(self) -> Self {
        c64(
            self.re.cos() * self.im.cosh(),
            -self.re.sin() * self.im.sinh(),
        )
    }

    /// True if either component is NaN.
    #[inline]
    pub fn is_nan(self) -> bool {
        self.re.is_nan() || self.im.is_nan()
    }

    /// True if both components are finite.
    #[inline]
    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, s: f64) -> Self {
        c64(self.re * s, self.im * s)
    }
}

impl fmt::Debug for c64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({:e}{:+e}i)", self.re, self.im)
    }
}

impl fmt::Display for c64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{:+}i", self.re, self.im)
    }
}

impl From<f64> for c64 {
    #[inline]
    fn from(re: f64) -> Self {
        c64(re, 0.0)
    }
}

impl Neg for c64 {
    type Output = c64;
    #[inline]
    fn neg(self) -> c64 {
        c64(-self.re, -self.im)
    }
}

impl Add for c64 {
    type Output = c64;
    #[inline]
    fn add(self, o: c64) -> c64 {
        c64(self.re + o.re, self.im + o.im)
    }
}

impl Sub for c64 {
    type Output = c64;
    #[inline]
    fn sub(self, o: c64) -> c64 {
        c64(self.re - o.re, self.im - o.im)
    }
}

impl Mul for c64 {
    type Output = c64;
    #[inline]
    fn mul(self, o: c64) -> c64 {
        c64(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for c64 {
    type Output = c64;
    #[inline]
    fn div(self, o: c64) -> c64 {
        self * o.inv()
    }
}

impl Add<f64> for c64 {
    type Output = c64;
    #[inline]
    fn add(self, o: f64) -> c64 {
        c64(self.re + o, self.im)
    }
}

impl Sub<f64> for c64 {
    type Output = c64;
    #[inline]
    fn sub(self, o: f64) -> c64 {
        c64(self.re - o, self.im)
    }
}

impl Mul<f64> for c64 {
    type Output = c64;
    #[inline]
    fn mul(self, o: f64) -> c64 {
        c64(self.re * o, self.im * o)
    }
}

impl Div<f64> for c64 {
    type Output = c64;
    #[inline]
    fn div(self, o: f64) -> c64 {
        c64(self.re / o, self.im / o)
    }
}

impl Add<c64> for f64 {
    type Output = c64;
    #[inline]
    fn add(self, o: c64) -> c64 {
        c64(self + o.re, o.im)
    }
}

impl Sub<c64> for f64 {
    type Output = c64;
    #[inline]
    fn sub(self, o: c64) -> c64 {
        c64(self - o.re, -o.im)
    }
}

impl Mul<c64> for f64 {
    type Output = c64;
    #[inline]
    fn mul(self, o: c64) -> c64 {
        o * self
    }
}

impl Div<c64> for f64 {
    type Output = c64;
    #[inline]
    fn div(self, o: c64) -> c64 {
        c64::real(self) / o
    }
}

impl AddAssign for c64 {
    #[inline]
    fn add_assign(&mut self, o: c64) {
        *self = *self + o;
    }
}

impl SubAssign for c64 {
    #[inline]
    fn sub_assign(&mut self, o: c64) {
        *self = *self - o;
    }
}

impl MulAssign for c64 {
    #[inline]
    fn mul_assign(&mut self, o: c64) {
        *self = *self * o;
    }
}

impl DivAssign for c64 {
    #[inline]
    fn div_assign(&mut self, o: c64) {
        *self = *self / o;
    }
}

impl Sum for c64 {
    fn sum<I: Iterator<Item = c64>>(iter: I) -> c64 {
        iter.fold(c64::ZERO, |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: c64, b: c64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn arithmetic_identities() {
        let z = c64(3.0, -4.0);
        let w = c64(-1.5, 2.5);
        assert_eq!(z + w - w, z);
        assert!(close(z * w / w, z, 1e-15));
        assert_eq!(-(-z), z);
        assert_eq!(z * c64::ONE, z);
        assert_eq!(z + c64::ZERO, z);
    }

    #[test]
    fn abs_and_norm() {
        let z = c64(3.0, 4.0);
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z.norm_sqr(), 25.0);
        // hypot path avoids overflow
        let big = c64(1e308, 1e308);
        assert!(big.abs().is_finite());
    }

    #[test]
    fn conj_properties() {
        let z = c64(1.2, -0.7);
        assert_eq!(z.conj().conj(), z);
        let zz = z * z.conj();
        assert!((zz.im).abs() < 1e-16);
        assert!((zz.re - z.norm_sqr()).abs() < 1e-15);
    }

    #[test]
    fn inv_is_reciprocal() {
        for &z in &[c64(2.0, 0.0), c64(0.0, -3.0), c64(1e-200, 4.0), c64(5.0, 1e200)] {
            assert!(close(z * z.inv(), c64::ONE, 1e-14), "{z:?}");
        }
    }

    #[test]
    fn sqrt_branch() {
        assert!(close(c64(-1.0, 0.0).sqrt(), c64::I, 1e-15));
        let z = c64(-2.0, -1e-30);
        assert!(z.sqrt().im < 0.0); // just below the cut -> negative imag
        for &z in &[c64(2.0, 3.0), c64(-5.0, 0.1), c64(0.0, -2.0)] {
            let r = z.sqrt();
            assert!(close(r * r, z, 1e-14));
            assert!(r.re >= 0.0);
        }
    }

    #[test]
    fn exp_ln_roundtrip() {
        let z = c64(0.3, -1.1);
        assert!(close(z.exp().ln(), z, 1e-14));
        // Euler
        assert!(close(c64(0.0, std::f64::consts::PI).exp(), c64(-1.0, 0.0), 1e-15));
    }

    #[test]
    fn trig_identity() {
        let z = c64(0.7, 0.4);
        let s = z.sin();
        let c = z.cos();
        assert!(close(s * s + c * c, c64::ONE, 1e-14));
    }

    #[test]
    fn powi_matches_repeated_mul() {
        let z = c64(1.1, -0.3);
        let mut acc = c64::ONE;
        for n in 0..8 {
            assert!(close(z.powi(n), acc, 1e-13));
            acc *= z;
        }
        assert!(close(z.powi(-3) * z.powi(3), c64::ONE, 1e-13));
    }

    #[test]
    fn powc_consistency() {
        let z = c64(2.0, 1.0);
        assert!(close(z.powc(c64(2.0, 0.0)), z * z, 1e-13));
    }

    #[test]
    fn sum_iterator() {
        let v = vec![c64(1.0, 2.0); 10];
        let s: c64 = v.iter().copied().sum();
        assert_eq!(s, c64(10.0, 20.0));
    }

    #[test]
    fn layout_is_interleaved_f64() {
        assert_eq!(std::mem::size_of::<c64>(), 16);
        let v = [c64(1.0, 2.0), c64(3.0, 4.0)];
        let f: &[f64] =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const f64, 4) };
        assert_eq!(f, &[1.0, 2.0, 3.0, 4.0]);
    }
}
