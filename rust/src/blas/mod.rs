//! Column-major (Fortran) BLAS semantics over the dispatcher — the
//! layer the `rust_pallas_abi` cdylib exports as `dgemm_`/`zgemm_`, and
//! the home of the process-global dispatcher an intercepted binary
//! runs against.
//!
//! ## The transpose trick
//!
//! The dispatcher's kernels are row-major.  Rather than copy-transpose
//! every column-major operand, we use that a column-major `m x n`
//! result `C` (leading dimension `ldc`) viewed row-major **is** `C^T`:
//! computing `R = C^T = op(B)^T · op(A)^T` with the row-major kernels
//! lets every output column scatter contiguously, and the two gathers
//! `op(B)^T` / `op(A)^T` are plain strided views of the original
//! buffers ([`crate::kernels::pack::SrcView`]) — contiguous column
//! copies for `'N'` flags, `ld`-strided walks for `'T'`/`'C'`.
//!
//! ## Bit-exactness contract
//!
//! In fixed FP64 mode the delivered bits equal a textbook column-major
//! triple loop with ascending-`p` accumulation: the blocked kernel is
//! pinned bit-identical to [`crate::linalg::dgemm_naive`], IEEE
//! multiplication and addition are commutative bitwise (only grouping
//! matters, and the `p` order is preserved), and the `alpha`/`beta`
//! update applies the exact expression pinned in
//! [`crate::linalg::gemm_update_f64`].  The conformance suite
//! (`tests/blas_conformance.rs`) sweeps the full parameter surface
//! against such an oracle.
//!
//! ## Global dispatcher
//!
//! [`global`] lazily builds one process-wide [`Dispatcher`] from
//! environment variables only (`OZACCEL_*` / `OZIMMU_COMPUTE_MODE` —
//! no config file is consulted: an intercepted binary has no way to
//! pass one).  Malformed configuration is rejected loudly on first
//! BLAS call: a message on stderr and `exit(78)` (EX_CONFIG), never a
//! silently-default run.  Unless `OZACCEL_PEAK=0`, a crash-safe PEAK
//! report dump is registered via `atexit` (to stderr, or to
//! `OZACCEL_PEAK_FILE` when set) and the panic-hook crash dump is
//! armed, so even an intercepted binary that never calls back into us
//! leaves its offload profile behind.

use std::sync::{Arc, OnceLock};

use crate::complex::c64;
use crate::coordinator::{CallSiteId, Dispatcher};
use crate::error::{Error, Result};
use crate::kernels::pack::SrcView;
use crate::linalg::{gemm_scale_c64, gemm_scale_f64, gemm_update_c64, gemm_update_f64, Mat, ZMat};

/// A BLAS transpose flag (`transa` / `transb`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    /// `'N'`: operand used as stored.
    No,
    /// `'T'`: operand used transposed.
    Transpose,
    /// `'C'`: operand used conjugate-transposed (same as `'T'` for
    /// real matrices).
    ConjTranspose,
}

impl Trans {
    /// Parse a Fortran transpose character (case-insensitive `N`, `T`,
    /// `C`); anything else is an illegal parameter.
    pub fn parse(c: u8) -> Option<Trans> {
        match c {
            b'N' | b'n' => Some(Trans::No),
            b'T' | b't' => Some(Trans::Transpose),
            b'C' | b'c' => Some(Trans::ConjTranspose),
            _ => None,
        }
    }

    /// Whether the flag transposes the operand.
    pub fn is_trans(self) -> bool {
        !matches!(self, Trans::No)
    }
}

/// Validated geometry of one column-major GEMM call: dimensions,
/// leading dimensions, transpose flags.
#[derive(Clone, Copy, Debug)]
pub struct GemmGeom {
    /// `op(A)` selector.
    pub transa: Trans,
    /// `op(B)` selector.
    pub transb: Trans,
    /// Rows of `op(A)` and of `C`.
    pub m: usize,
    /// Columns of `op(B)` and of `C`.
    pub n: usize,
    /// Contraction depth (columns of `op(A)`, rows of `op(B)`).
    pub k: usize,
    /// Leading dimension of the `A` buffer.
    pub lda: usize,
    /// Leading dimension of the `B` buffer.
    pub ldb: usize,
    /// Leading dimension of the `C` buffer.
    pub ldc: usize,
}

impl GemmGeom {
    /// Validate raw Fortran GEMM arguments exactly as the reference
    /// BLAS does, returning the 1-based index of the first illegal
    /// parameter on failure (`transa`=1, `transb`=2, `m`=3, `n`=4,
    /// `k`=5, `lda`=8, `ldb`=10, `ldc`=13) — the number an
    /// `xerbla`-style diagnostic reports.
    #[allow(clippy::too_many_arguments)]
    pub fn check(
        transa: u8,
        transb: u8,
        m: i64,
        n: i64,
        k: i64,
        lda: i64,
        ldb: i64,
        ldc: i64,
    ) -> std::result::Result<GemmGeom, u32> {
        let ta = Trans::parse(transa).ok_or(1u32)?;
        let tb = Trans::parse(transb).ok_or(2u32)?;
        if m < 0 {
            return Err(3);
        }
        if n < 0 {
            return Err(4);
        }
        if k < 0 {
            return Err(5);
        }
        let nrowa = if ta.is_trans() { k } else { m };
        let nrowb = if tb.is_trans() { n } else { k };
        if lda < nrowa.max(1) {
            return Err(8);
        }
        if ldb < nrowb.max(1) {
            return Err(10);
        }
        if ldc < m.max(1) {
            return Err(13);
        }
        Ok(GemmGeom {
            transa: ta,
            transb: tb,
            m: m as usize,
            n: n as usize,
            k: k as usize,
            lda: lda as usize,
            ldb: ldb as usize,
            ldc: ldc as usize,
        })
    }

    /// Minimal legal element count of the `A` buffer
    /// (`lda·(cols−1) + rows` — BLAS guarantees no more).
    pub fn a_len(&self) -> usize {
        let (rows, cols) = if self.transa.is_trans() {
            (self.k, self.m)
        } else {
            (self.m, self.k)
        };
        colbuf_len(rows, cols, self.lda)
    }

    /// Minimal legal element count of the `B` buffer.
    pub fn b_len(&self) -> usize {
        let (rows, cols) = if self.transb.is_trans() {
            (self.n, self.k)
        } else {
            (self.k, self.n)
        };
        colbuf_len(rows, cols, self.ldb)
    }

    /// Minimal legal element count of the `C` buffer.
    pub fn c_len(&self) -> usize {
        colbuf_len(self.m, self.n, self.ldc)
    }
}

/// Minimal length of a column-major `rows x cols` buffer with leading
/// dimension `ld` (0 when either extent is 0).
fn colbuf_len(rows: usize, cols: usize, ld: usize) -> usize {
    if rows == 0 || cols == 0 {
        0
    } else {
        (cols - 1) * ld + rows
    }
}

/// Gather `op(A)^T` (`k x m`, row-major) from a column-major `A`
/// buffer; `conj` applies only to the complex instantiation's `'C'`
/// flag.
fn gather_a_f64(g: &GemmGeom, a: &[f64]) -> Mat<f64> {
    if g.transa.is_trans() {
        // A is k x m column-major: op(A)^T[p, i] = A[p + i·lda].
        SrcView::colmajor_rows(a, g.k, g.m, g.lda).to_mat()
    } else {
        // A is m x k column-major: op(A)^T[p, i] = A[i + p·lda].
        SrcView::colmajor_cols(a, g.m, g.k, g.lda).to_mat()
    }
}

/// Gather `op(B)^T` (`n x k`, row-major) from a column-major `B`
/// buffer.
fn gather_b_f64(g: &GemmGeom, b: &[f64]) -> Mat<f64> {
    if g.transb.is_trans() {
        // B is n x k column-major: op(B)^T[j, p] = B[j + p·ldb].
        SrcView::colmajor_rows(b, g.n, g.k, g.ldb).to_mat()
    } else {
        // B is k x n column-major: op(B)^T[j, p] = B[p + j·ldb].
        SrcView::colmajor_cols(b, g.k, g.n, g.ldb).to_mat()
    }
}

/// Complex twin of [`gather_a_f64`]; the `'C'` flag conjugates during
/// the gather.
fn gather_a_c64(g: &GemmGeom, a: &[c64]) -> ZMat {
    let view = if g.transa.is_trans() {
        SrcView::colmajor_rows(a, g.k, g.m, g.lda)
    } else {
        SrcView::colmajor_cols(a, g.m, g.k, g.lda)
    };
    if g.transa == Trans::ConjTranspose {
        view.map_mat(|z| z.conj())
    } else {
        view.to_mat()
    }
}

/// Complex twin of [`gather_b_f64`].
fn gather_b_c64(g: &GemmGeom, b: &[c64]) -> ZMat {
    let view = if g.transb.is_trans() {
        SrcView::colmajor_rows(b, g.n, g.k, g.ldb)
    } else {
        SrcView::colmajor_cols(b, g.k, g.n, g.ldb)
    };
    if g.transb == Trans::ConjTranspose {
        view.map_mat(|z| z.conj())
    } else {
        view.to_mat()
    }
}

/// Check the caller's slices cover the geometry's minimal lengths.
fn check_lens(g: &GemmGeom, a_len: usize, b_len: usize, c_len: usize) -> Result<()> {
    if a_len < g.a_len() || b_len < g.b_len() || c_len < g.c_len() {
        return Err(Error::Shape(format!(
            "gemm buffers too short for geometry {g:?}: a={a_len}/{}, b={b_len}/{}, c={c_len}/{}",
            g.a_len(),
            g.b_len(),
            g.c_len()
        )));
    }
    Ok(())
}

/// Full column-major DGEMM `C := alpha·op(A)·op(B) + beta·C` through a
/// dispatcher, attributed to `site`.  BLAS quick returns apply:
/// `m == 0` or `n == 0` touches nothing, and `alpha == 0` or `k == 0`
/// only scales `C` (with `beta == 0` overwriting, never reading —
/// NaN-poisoned output buffers are legal).
#[allow(clippy::too_many_arguments)]
pub fn dgemm_colmajor(
    d: &Dispatcher,
    site: CallSiteId,
    g: &GemmGeom,
    alpha: f64,
    a: &[f64],
    b: &[f64],
    beta: f64,
    c: &mut [f64],
) -> Result<()> {
    check_lens(g, a.len(), b.len(), c.len())?;
    if g.m == 0 || g.n == 0 {
        return Ok(());
    }
    if alpha == 0.0 || g.k == 0 {
        for j in 0..g.n {
            for v in &mut c[j * g.ldc..j * g.ldc + g.m] {
                *v = gemm_scale_f64(beta, *v);
            }
        }
        return Ok(());
    }
    // R = C^T = op(B)^T · op(A)^T, n x m row-major.
    let f1 = gather_b_f64(g, b);
    let f2 = gather_a_f64(g, a);
    let r = d.dgemm_at(site, d.mode(), &f1, &f2)?;
    for j in 0..g.n {
        let rrow = r.row(j);
        let ccol = &mut c[j * g.ldc..j * g.ldc + g.m];
        for (cv, &pv) in ccol.iter_mut().zip(rrow) {
            *cv = gemm_update_f64(alpha, pv, beta, *cv);
        }
    }
    Ok(())
}

/// Full column-major ZGEMM `C := alpha·op(A)·op(B) + beta·C` (complex
/// scalars; `'C'` flags conjugate-transpose).  Same quick-return and
/// overwrite-at-`beta == 0` rules as [`dgemm_colmajor`].
#[allow(clippy::too_many_arguments)]
pub fn zgemm_colmajor(
    d: &Dispatcher,
    site: CallSiteId,
    g: &GemmGeom,
    alpha: c64,
    a: &[c64],
    b: &[c64],
    beta: c64,
    c: &mut [c64],
) -> Result<()> {
    check_lens(g, a.len(), b.len(), c.len())?;
    if g.m == 0 || g.n == 0 {
        return Ok(());
    }
    if (alpha.re == 0.0 && alpha.im == 0.0) || g.k == 0 {
        for j in 0..g.n {
            for v in &mut c[j * g.ldc..j * g.ldc + g.m] {
                *v = gemm_scale_c64(beta, *v);
            }
        }
        return Ok(());
    }
    let f1 = gather_b_c64(g, b);
    let f2 = gather_a_c64(g, a);
    let r = d.zgemm_at(site, d.mode(), &f1, &f2)?;
    for j in 0..g.n {
        let rrow = r.row(j);
        let ccol = &mut c[j * g.ldc..j * g.ldc + g.m];
        for (cv, &pv) in ccol.iter_mut().zip(rrow) {
            *cv = gemm_update_c64(alpha, pv, beta, *cv);
        }
    }
    Ok(())
}

/// The process-global dispatcher behind the exported BLAS symbols.
static GLOBAL: OnceLock<Arc<Dispatcher>> = OnceLock::new();

/// The lazily-initialized process-global [`Dispatcher`], configured
/// from environment variables only (see the module docs).  First call
/// builds it; malformed `OZACCEL_*` configuration prints
/// `ozaccel: abi init failed: ...` on stderr and terminates the
/// process with exit code 78 (EX_CONFIG) — an intercepted binary must
/// never silently run with defaults it did not ask for.
pub fn global() -> &'static Arc<Dispatcher> {
    GLOBAL.get_or_init(|| match std::panic::catch_unwind(build_global) {
        Ok(Ok(d)) => d,
        Ok(Err(e)) => init_die(&e.to_string()),
        Err(p) => init_die(panic_text(&p)),
    })
}

/// Build the global dispatcher: env-only configuration, then (unless
/// `OZACCEL_PEAK=0`) the `atexit` PEAK dump and the panic-hook crash
/// dump.
fn build_global() -> Result<Arc<Dispatcher>> {
    let mut cfg = crate::config::RunConfig::default();
    cfg.apply_env()?;
    let d = Arc::new(Dispatcher::new(cfg.dispatch)?);
    if peak_enabled() {
        d.enable_crash_dump();
        crate::coordinator::crash::install_hook();
        // Safety: libc atexit with a non-unwinding extern "C" callback.
        unsafe { atexit(peak_atexit) };
    }
    Ok(d)
}

fn init_die(msg: &str) -> ! {
    eprintln!("ozaccel: abi init failed: {msg}");
    // EX_CONFIG — deterministic, subprocess-testable loud rejection.
    std::process::exit(78);
}

/// Render a caught panic payload (the loud env-rejection messages are
/// `String` panics).
fn panic_text(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<String>() {
        s
    } else if let Some(s) = p.downcast_ref::<&'static str>() {
        s
    } else {
        "unknown panic during init"
    }
}

/// Whether the atexit PEAK dump is enabled (`OZACCEL_PEAK`, default
/// on; `0`/`false`/`off` disable, anything else is rejected loudly).
fn peak_enabled() -> bool {
    match std::env::var("OZACCEL_PEAK") {
        Err(_) => true,
        Ok(raw) => match raw.trim() {
            "1" | "true" | "on" => true,
            "0" | "false" | "off" => false,
            _ => crate::util::env::invalid("OZACCEL_PEAK", &raw, "0|1|true|false|on|off"),
        },
    }
}

extern "C" {
    /// libc `atexit` — registered directly (no `libc` crate offline).
    fn atexit(cb: extern "C" fn()) -> i32;
}

/// The `atexit` callback: best-effort PEAK dump, never unwinding
/// across the C boundary.
extern "C" fn peak_atexit() {
    let _ = std::panic::catch_unwind(dump_peak);
}

/// Render the global dispatcher's PEAK report to `OZACCEL_PEAK_FILE`
/// (or stderr when unset) — crash-safe (`try_report`): a contended
/// lock skips the dump rather than deadlocking exit.
fn dump_peak() {
    let Some(d) = GLOBAL.get() else { return };
    let Some(rep) = d.try_report() else { return };
    let mut text = rep.render();
    if !text.ends_with('\n') {
        text.push('\n');
    }
    match std::env::var("OZACCEL_PEAK_FILE") {
        Ok(path) if !path.trim().is_empty() => {
            if let Err(e) = std::fs::write(path.trim(), text.as_bytes()) {
                eprintln!("ozaccel: PEAK dump to OZACCEL_PEAK_FILE failed: {e}");
            }
        }
        _ => eprint!("{text}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DispatchConfig;
    use crate::ozaki::ComputeMode;
    use crate::testing::Rng;

    fn host(mode: ComputeMode) -> Dispatcher {
        Dispatcher::new(DispatchConfig::host_only(mode)).unwrap()
    }

    /// Column-major textbook oracle with ascending-p accumulation and
    /// the shared scalar update — the in-module smoke twin of the full
    /// conformance suite's oracle.
    fn oracle_dgemm(g: &GemmGeom, alpha: f64, a: &[f64], b: &[f64], beta: f64, c: &mut [f64]) {
        let opa = |i: usize, p: usize| {
            if g.transa.is_trans() {
                a[p + i * g.lda]
            } else {
                a[i + p * g.lda]
            }
        };
        let opb = |p: usize, j: usize| {
            if g.transb.is_trans() {
                b[j + p * g.ldb]
            } else {
                b[p + j * g.ldb]
            }
        };
        for j in 0..g.n {
            for i in 0..g.m {
                let idx = i + j * g.ldc;
                if alpha == 0.0 || g.k == 0 {
                    c[idx] = gemm_scale_f64(beta, c[idx]);
                } else {
                    let mut acc = 0.0;
                    for p in 0..g.k {
                        acc += opa(i, p) * opb(p, j);
                    }
                    c[idx] = gemm_update_f64(alpha, acc, beta, c[idx]);
                }
            }
        }
    }

    #[test]
    fn trans_parse_covers_the_fortran_surface() {
        assert_eq!(Trans::parse(b'N'), Some(Trans::No));
        assert_eq!(Trans::parse(b'n'), Some(Trans::No));
        assert_eq!(Trans::parse(b'T'), Some(Trans::Transpose));
        assert_eq!(Trans::parse(b'c'), Some(Trans::ConjTranspose));
        assert_eq!(Trans::parse(b'X'), None);
        assert_eq!(Trans::parse(0), None);
    }

    #[test]
    fn geom_check_reports_blas_parameter_numbers() {
        let ok = GemmGeom::check(b'N', b'T', 3, 4, 5, 3, 4, 3).unwrap();
        assert_eq!((ok.m, ok.n, ok.k), (3, 4, 5));
        assert_eq!(GemmGeom::check(b'Q', b'N', 1, 1, 1, 1, 1, 1), Err(1));
        assert_eq!(GemmGeom::check(b'N', b'5', 1, 1, 1, 1, 1, 1), Err(2));
        assert_eq!(GemmGeom::check(b'N', b'N', -1, 1, 1, 1, 1, 1), Err(3));
        assert_eq!(GemmGeom::check(b'N', b'N', 1, -1, 1, 1, 1, 1), Err(4));
        assert_eq!(GemmGeom::check(b'N', b'N', 1, 1, -1, 1, 1, 1), Err(5));
        // lda validates against op-dependent row counts.
        assert_eq!(GemmGeom::check(b'N', b'N', 4, 2, 3, 3, 3, 4), Err(8));
        assert_eq!(GemmGeom::check(b'T', b'N', 4, 2, 3, 3, 3, 4).map(|g| g.lda), Ok(3));
        assert_eq!(GemmGeom::check(b'N', b'N', 4, 2, 3, 4, 2, 4), Err(10));
        assert_eq!(GemmGeom::check(b'N', b'T', 4, 2, 3, 4, 2, 4).map(|g| g.ldb), Ok(2));
        assert_eq!(GemmGeom::check(b'N', b'N', 4, 2, 3, 4, 3, 3), Err(13));
        // degenerate dims are legal with ld >= 1
        let z = GemmGeom::check(b'N', b'N', 0, 0, 0, 1, 1, 1).unwrap();
        assert_eq!((z.a_len(), z.b_len(), z.c_len()), (0, 0, 0));
    }

    #[test]
    fn colmajor_dgemm_matches_the_oracle_bitwise() {
        let d = host(ComputeMode::Dgemm);
        let mut rng = Rng::new(61);
        for (ta, tb) in [(b'N', b'N'), (b'N', b'T'), (b'T', b'N'), (b'C', b'C')] {
            let (m, n, k) = (7i64, 5, 6);
            let lda = if ta == b'N' { m + 2 } else { k + 2 };
            let ldb = if tb == b'N' { k + 1 } else { n + 1 };
            let g = GemmGeom::check(ta, tb, m, n, k, lda, ldb, m + 3).unwrap();
            let a: Vec<f64> = (0..g.a_len()).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..g.b_len()).map(|_| rng.normal()).collect();
            let c0: Vec<f64> = (0..g.c_len()).map(|_| rng.normal()).collect();
            let (mut got, mut want) = (c0.clone(), c0);
            dgemm_colmajor(&d, "blas:test", &g, 0.7, &a, &b, -0.5, &mut got).unwrap();
            oracle_dgemm(&g, 0.7, &a, &b, -0.5, &mut want);
            assert_eq!(got, want, "ta={} tb={}", ta as char, tb as char);
        }
    }

    #[test]
    fn colmajor_update_leaves_ld_padding_untouched() {
        let d = host(ComputeMode::Dgemm);
        let g = GemmGeom::check(b'N', b'N', 2, 2, 2, 2, 2, 4).unwrap();
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        // c is 2x2 in a ldc=4 buffer; rows 2..4 of each column are
        // padding and must come back byte-identical.
        let mut c = vec![9.0; g.c_len()];
        dgemm_colmajor(&d, "blas:test", &g, 1.0, &a, &b, 0.0, &mut c).unwrap();
        assert_eq!(c[0], 2.0);
        assert_eq!(c[1], 2.0);
        assert_eq!((c[2], c[3]), (9.0, 9.0), "ld padding preserved");
        assert_eq!((c[4], c[5]), (2.0, 2.0));
    }

    #[test]
    fn colmajor_zgemm_conjugates_on_c_flags() {
        let d = host(ComputeMode::Dgemm);
        let mut rng = Rng::new(62);
        let (m, n, k) = (4usize, 3, 5);
        // 'C' on both sides, padded lds.
        let (ml, nl, kl) = (m as i64, n as i64, k as i64);
        let g = GemmGeom::check(b'C', b'C', ml, nl, kl, kl + 1, nl + 2, ml + 1).unwrap();
        let a: Vec<c64> = (0..g.a_len()).map(|_| rng.cnormal()).collect();
        let b: Vec<c64> = (0..g.b_len()).map(|_| rng.cnormal()).collect();
        let mut got = vec![c64(f64::NAN, f64::NAN); g.c_len()];
        let alpha = c64(1.0, 0.0);
        zgemm_colmajor(&d, "blas:test", &g, alpha, &a, &b, c64(0.0, 0.0), &mut got).unwrap();
        // Independent gather-free check of one element: C[i,j] =
        // sum_p conj(A[j? ...]) — spell it directly from the buffers.
        for i in 0..m {
            for j in 0..n {
                let mut rr = 0.0;
                let mut ii = 0.0;
                let mut ri = 0.0;
                let mut ir = 0.0;
                for p in 0..k {
                    let av = a[p + i * g.lda].conj();
                    let bv = b[j + p * g.ldb].conj();
                    rr += av.re * bv.re;
                    ii += av.im * bv.im;
                    ri += av.re * bv.im;
                    ir += av.im * bv.re;
                }
                let want = c64(rr - ii, ri + ir);
                let gv = got[i + j * g.ldc];
                assert!(
                    (gv - want).abs() <= 1e-12 * (1.0 + want.abs()),
                    "({i},{j}): {gv:?} vs {want:?}"
                );
            }
        }
    }

    #[test]
    fn short_buffers_are_rejected_not_read() {
        let d = host(ComputeMode::Dgemm);
        let g = GemmGeom::check(b'N', b'N', 3, 3, 3, 3, 3, 3).unwrap();
        let a = vec![0.0; g.a_len() - 1];
        let b = vec![0.0; g.b_len()];
        let mut c = vec![0.0; g.c_len()];
        assert!(dgemm_colmajor(&d, "blas:test", &g, 1.0, &a, &b, 0.0, &mut c).is_err());
    }

    #[test]
    fn peak_enabled_parses_the_documented_values() {
        let _guard = crate::testing::env_lock();
        let cases = [("1", true), ("true", true), ("on", true), ("0", false), ("off", false)];
        for (v, want) in cases {
            std::env::set_var("OZACCEL_PEAK", v);
            assert_eq!(peak_enabled(), want, "OZACCEL_PEAK={v}");
        }
        std::env::remove_var("OZACCEL_PEAK");
        assert!(peak_enabled(), "default is on");
        std::env::set_var("OZACCEL_PEAK", "maybe");
        let caught = std::panic::catch_unwind(peak_enabled);
        std::env::remove_var("OZACCEL_PEAK");
        assert!(caught.is_err(), "malformed OZACCEL_PEAK is loud");
    }
}
