//! The precision subsystem — closed-loop, per-call-site split selection.
//!
//! The paper's §4 proposal ("dynamically adjusting the split number in
//! that region") lived in `coordinator/adaptive.rs` as a static
//! a-priori policy: callers had to hand it a condition number, only the
//! target was configurable, and the chosen splits never fed back from
//! observed error.  This module promotes precision selection to a
//! first-class subsystem with three escalating modes
//! (`OZACCEL_PRECISION` / `run.precision.*`):
//!
//! * [`PrecisionMode::Fixed`] — the dispatcher's configured
//!   `ComputeMode` is used verbatim (the paper's Table-1 columns);
//! * [`PrecisionMode::Apriori`] — per call site, the split count is
//!   re-derived on every call by inverting the Ozaki forward error
//!   bound ([`crate::ozaki::required_splits_in`]) against the latest
//!   consumer condition number fed to the governor;
//! * [`PrecisionMode::Feedback`] — the a-priori choice seeds a per-site
//!   state that is then *measured*: a deterministic sample of output
//!   rows is recomputed in FP64 ([`probe_dgemm`] / [`probe_zgemm`]),
//!   the observed residual calibrates the error-model constant
//!   ([`crate::ozaki::implied_constant`]), and the split count ramps up
//!   or down with hysteresis (up/down thresholds and a cooldown) —
//!   resonance-region energy points climb to many slices while
//!   well-conditioned points walk down to 3–4.
//!
//! The governor is keyed by the same interned call-site ids the PEAK
//! profiler uses ([`crate::coordinator::CallSiteId`]), so its state
//! lines up one-to-one with the rows of the per-site report, where the
//! split trajectory and probe cost show up as the `splits` and
//! `probe_ms` columns.
//!
//! Invariants (pinned by `tests/precision_governor.rs`):
//!
//! * every emulated decision satisfies
//!   `min_splits <= splits <= max_splits` — the governor has no panic
//!   path and never leaves the configured window;
//! * the a-priori seed is monotone: tighter targets and larger κ never
//!   decrease the split count;
//! * probe row sampling and the probe residual are bit-identical for a
//!   fixed seed, regardless of the thread that computes them.

mod governor;
mod probe;
mod site_state;

pub use governor::{Decision, Governor, SiteSnapshot};
pub use probe::{probe_dgemm, probe_seed, probe_zgemm, sample_rows, ProbeReport};
pub use site_state::{push_trajectory, SiteState, TRAJECTORY_CAP};

use crate::error::{Error, Result};
use crate::ozaki::{MAX_SPLITS, MIN_SPLITS};

/// How the precision of emulated GEMMs is chosen
/// (`OZACCEL_PRECISION` / `run.precision.mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecisionMode {
    /// Use the requested `ComputeMode` verbatim (no governing).
    Fixed,
    /// Re-derive the split count from the a-priori error bound and the
    /// latest consumer κ on every call.
    Apriori,
    /// Seed a-priori, then close the loop with FP64 probes and
    /// hysteresis (the tentpole feedback governor).
    Feedback,
    /// Feedback governing plus an a-posteriori certificate on **every**
    /// emulated call: the deterministic row probe is compared against
    /// the configured target, and a violating call escalates — ramped
    /// splits first, native FP64 last — so the returned result always
    /// satisfies the bound (`run.precision.certify`).
    Certified,
}

impl PrecisionMode {
    /// Parse `fixed`, `apriori`, `feedback`, or `certified` (rejects
    /// anything else loudly — this backs both `OZACCEL_PRECISION` and
    /// `run.precision.mode`).
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fixed" => Ok(PrecisionMode::Fixed),
            "apriori" | "a-priori" => Ok(PrecisionMode::Apriori),
            "feedback" => Ok(PrecisionMode::Feedback),
            "certified" | "certify" => Ok(PrecisionMode::Certified),
            other => Err(Error::Config(format!(
                "bad precision mode {other:?} (expected fixed | apriori | feedback | certified)"
            ))),
        }
    }

    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            PrecisionMode::Fixed => "fixed",
            PrecisionMode::Apriori => "apriori",
            PrecisionMode::Feedback => "feedback",
            PrecisionMode::Certified => "certified",
        }
    }

    /// Whether this mode runs the measured feedback loop (probes,
    /// residual calibration, hysteresis).  [`PrecisionMode::Certified`]
    /// is feedback *plus* the per-call certificate, so every governor
    /// branch that used to test `== Feedback` tests this instead.
    pub fn is_feedback_like(self) -> bool {
        matches!(self, PrecisionMode::Feedback | PrecisionMode::Certified)
    }
}

impl std::fmt::Display for PrecisionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Governor configuration (the `run.precision.*` surface).
#[derive(Clone, Copy, Debug)]
pub struct PrecisionConfig {
    /// Selection mode (fixed / apriori / feedback).
    pub mode: PrecisionMode,
    /// Target relative accuracy of downstream (consumer) results.
    pub target: f64,
    /// Floor for the split count (ozIMMU minimum is 3).
    pub min_splits: u32,
    /// Ceiling for the split count (cost guard; ozIMMU maximum is 18).
    pub max_splits: u32,
    /// Ramp up when the probed residual exceeds
    /// `up_threshold · target / κ`.
    pub up_threshold: f64,
    /// Consider ramping down when the probed residual is below
    /// `down_threshold · target / κ` (must stay `< up_threshold` so the
    /// hysteresis band is non-empty).
    pub down_threshold: f64,
    /// Probes to skip after a split change before adjusting again.
    pub cooldown: u32,
    /// Output rows recomputed in FP64 per probe.
    pub probe_rows: usize,
    /// Probe every Nth emulated call per site (1 = every call).
    pub probe_period: u32,
}

impl Default for PrecisionConfig {
    fn default() -> Self {
        PrecisionConfig {
            mode: PrecisionMode::Fixed,
            target: 1e-9,
            min_splits: MIN_SPLITS,
            max_splits: MAX_SPLITS,
            up_threshold: 1.0,
            down_threshold: 0.1,
            cooldown: 2,
            probe_rows: 2,
            probe_period: 4,
        }
    }
}

impl PrecisionConfig {
    /// Reject out-of-range or inconsistent settings loudly (used by the
    /// config parser after `run.precision.*` / `[adaptive]` aliases are
    /// applied).
    pub fn validate(&self) -> Result<()> {
        if !self.target.is_finite() || self.target <= 0.0 {
            return Err(Error::Config(format!(
                "precision.target must be a positive finite float, got {}",
                self.target
            )));
        }
        if self.min_splits < MIN_SPLITS || self.max_splits > MAX_SPLITS {
            return Err(Error::Config(format!(
                "precision splits window [{}, {}] outside the supported {MIN_SPLITS}..={MAX_SPLITS}",
                self.min_splits, self.max_splits
            )));
        }
        if self.min_splits > self.max_splits {
            return Err(Error::Config(format!(
                "precision.min_splits ({}) > precision.max_splits ({})",
                self.min_splits, self.max_splits
            )));
        }
        if !self.up_threshold.is_finite() || self.up_threshold <= 0.0 {
            return Err(Error::Config(format!(
                "precision.up_threshold must be a positive finite float, got {}",
                self.up_threshold
            )));
        }
        if !self.down_threshold.is_finite() || self.down_threshold <= 0.0 {
            return Err(Error::Config(format!(
                "precision.down_threshold must be a positive finite float, got {}",
                self.down_threshold
            )));
        }
        if self.down_threshold >= self.up_threshold {
            return Err(Error::Config(format!(
                "precision.down_threshold ({}) must be < precision.up_threshold ({}) \
                 or the hysteresis band is empty",
                self.down_threshold, self.up_threshold
            )));
        }
        if self.probe_rows == 0 {
            return Err(Error::Config(
                "precision.probe_rows must be >= 1".into(),
            ));
        }
        if self.probe_period == 0 {
            return Err(Error::Config(
                "precision.probe_period must be >= 1".into(),
            ));
        }
        Ok(())
    }

    /// A copy with every field forced into its legal range (defaults
    /// substituted for unusable values).  [`crate::precision::Governor`]
    /// normalizes on construction so its arithmetic stays total —
    /// no division by a zero probe period, no inverted clamp — even for
    /// configs built in code without [`PrecisionConfig::validate`];
    /// the config parser still rejects such configs loudly.
    pub fn normalized(mut self) -> Self {
        let d = PrecisionConfig::default();
        if !self.target.is_finite() || self.target <= 0.0 {
            self.target = d.target;
        }
        self.min_splits = self.min_splits.clamp(MIN_SPLITS, MAX_SPLITS);
        self.max_splits = self.max_splits.clamp(self.min_splits, MAX_SPLITS);
        if !self.up_threshold.is_finite() || self.up_threshold <= 0.0 {
            self.up_threshold = d.up_threshold;
        }
        if !self.down_threshold.is_finite()
            || self.down_threshold <= 0.0
            || self.down_threshold >= self.up_threshold
        {
            self.down_threshold = self.up_threshold * 0.1;
        }
        self.probe_rows = self.probe_rows.max(1);
        self.probe_period = self.probe_period.max(1);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_and_rejects() {
        assert_eq!(PrecisionMode::parse("fixed").unwrap(), PrecisionMode::Fixed);
        assert_eq!(
            PrecisionMode::parse(" APriori ").unwrap(),
            PrecisionMode::Apriori
        );
        assert_eq!(
            PrecisionMode::parse("feedback").unwrap(),
            PrecisionMode::Feedback
        );
        assert_eq!(
            PrecisionMode::parse("Certified").unwrap(),
            PrecisionMode::Certified
        );
        for bad in ["", "adaptive", "feed-back", "fixed8", "governed", "certifiedd"] {
            assert!(PrecisionMode::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn default_config_is_valid() {
        PrecisionConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_rejects_inconsistent_settings() {
        let base = PrecisionConfig::default();
        let cases = [
            PrecisionConfig { min_splits: 9, max_splits: 4, ..base },
            PrecisionConfig { min_splits: 2, ..base },
            PrecisionConfig { max_splits: 19, ..base },
            PrecisionConfig { target: 0.0, ..base },
            PrecisionConfig { target: f64::NAN, ..base },
            PrecisionConfig { up_threshold: 0.0, ..base },
            PrecisionConfig { down_threshold: 2.0, ..base },
            PrecisionConfig { down_threshold: 1.0, ..base },
            PrecisionConfig { probe_rows: 0, ..base },
            PrecisionConfig { probe_period: 0, ..base },
        ];
        for (i, c) in cases.iter().enumerate() {
            assert!(c.validate().is_err(), "case {i} accepted: {c:?}");
        }
    }

    #[test]
    fn normalized_makes_any_config_usable() {
        let bad = PrecisionConfig {
            mode: PrecisionMode::Feedback,
            target: -3.0,
            min_splits: 25,
            max_splits: 1,
            up_threshold: f64::NAN,
            down_threshold: 9.0,
            cooldown: 7,
            probe_rows: 0,
            probe_period: 0,
        };
        let n = bad.normalized();
        n.validate().expect("normalized config must validate");
        assert!(n.min_splits <= n.max_splits);
        assert!((3..=18).contains(&n.min_splits));
        assert!(n.target > 0.0);
        assert!(n.down_threshold < n.up_threshold);
        assert!(n.probe_rows >= 1 && n.probe_period >= 1);
        assert_eq!(n.cooldown, 7, "in-range fields pass through");
        // an already-valid config is untouched
        let ok = PrecisionConfig::default().normalized();
        assert_eq!(format!("{ok:?}"), format!("{:?}", PrecisionConfig::default()));
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in [
            PrecisionMode::Fixed,
            PrecisionMode::Apriori,
            PrecisionMode::Feedback,
            PrecisionMode::Certified,
        ] {
            assert_eq!(PrecisionMode::parse(m.name()).unwrap(), m);
            assert_eq!(format!("{m}"), m.name());
        }
    }

    #[test]
    fn feedback_likeness_is_exactly_feedback_and_certified() {
        assert!(!PrecisionMode::Fixed.is_feedback_like());
        assert!(!PrecisionMode::Apriori.is_feedback_like());
        assert!(PrecisionMode::Feedback.is_feedback_like());
        assert!(PrecisionMode::Certified.is_feedback_like());
    }
}
