//! A-posteriori precision probes: recompute a deterministic sample of
//! output rows in FP64 and report the observed relative residual of the
//! emulated result.
//!
//! A probe costs `rows · K · N` FLOPs against the GEMM's
//! `M · K · N · s(s+1)/2` slice products, so sampling a couple of rows
//! every few calls is orders of magnitude below the emulation itself;
//! the dispatcher attributes the measured probe seconds to the call
//! site (`probe_ms` PEAK column).
//!
//! Determinism: row selection is a seeded partial Fisher–Yates over the
//! SplitMix64 PRNG, and the FP64 recomputation runs the blocked kernels
//! pinned to one thread — both are bit-identical for a fixed seed no
//! matter which thread executes them (pinned by
//! `tests/precision_governor.rs`).

use std::time::Instant;

use crate::error::Result;
use crate::kernels::{dgemm_blocked, zgemm_blocked, KernelConfig};
use crate::linalg::{Mat, ZMat};
use crate::testing::Rng;

/// Outcome of one probe.
#[derive(Clone, Debug)]
pub struct ProbeReport {
    /// Max relative residual over the sampled rows
    /// (`max |emul − exact| / max |exact|`, both over the sample).
    pub rel_err: f64,
    /// Row indices that were recomputed (sorted, distinct).
    pub rows: Vec<usize>,
    /// Wall seconds the probe took.
    pub seconds: f64,
}

/// Deterministic probe seed from the call-site id, the GEMM shape, and
/// the per-site probe ordinal (FNV-1a).
pub fn probe_seed(site: &str, m: usize, k: usize, n: usize, ordinal: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in site.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    for v in [m as u64, k as u64, n as u64, ordinal] {
        h = (h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Sample `want` distinct row indices from `0..m` (partial
/// Fisher–Yates, seeded; sorted output).  Returns all rows when
/// `want >= m` and the empty set when `m == 0` or `want == 0`.
pub fn sample_rows(seed: u64, m: usize, want: usize) -> Vec<usize> {
    if m == 0 || want == 0 {
        return Vec::new();
    }
    let want = want.min(m);
    let mut rng = Rng::new(seed);
    let mut swap: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    let mut out = Vec::with_capacity(want);
    for i in 0..want {
        let j = rng.index(i, m);
        let vi = *swap.get(&i).unwrap_or(&i);
        let vj = *swap.get(&j).unwrap_or(&j);
        out.push(vj);
        swap.insert(j, vi);
    }
    out.sort_unstable();
    out
}

/// One probe body shared by the real and complex entry points: build
/// the row-subset of `a`, recompute it exactly with `gemm`, and reduce
/// the sampled residual with `abs` / `diff` (`|x|` and `|x − y|` for
/// the element type).  Keeping a single body means the probe protocol
/// (sampling, scaling, timing) cannot drift between the two dtypes.
fn probe_with<T, G, A, D>(
    a: &Mat<T>,
    b: &Mat<T>,
    c_emul: &Mat<T>,
    rows: &[usize],
    gemm: G,
    abs: A,
    diff: D,
) -> Result<ProbeReport>
where
    T: Copy + Default,
    G: FnOnce(&Mat<T>, &Mat<T>) -> Result<Mat<T>>,
    A: Fn(T) -> f64,
    D: Fn(T, T) -> f64,
{
    let t0 = Instant::now();
    let k = a.cols();
    let n = b.cols();
    let sub = Mat::from_fn(rows.len(), k, |i, j| a.get(rows[i], j));
    let exact = gemm(&sub, b)?;
    let mut err = 0.0f64;
    let mut scale = 0.0f64;
    for (i, &r) in rows.iter().enumerate() {
        for j in 0..n {
            let e = exact.get(i, j);
            scale = scale.max(abs(e));
            err = err.max(diff(c_emul.get(r, j), e));
        }
    }
    let rel_err = if scale > 0.0 { err / scale } else { err };
    Ok(ProbeReport {
        rel_err,
        rows: rows.to_vec(),
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Recompute `rows` of `a·b` in FP64 ([`dgemm_blocked`], pinned to one
/// thread) and compare against the emulated result `c_emul`.
pub fn probe_dgemm(
    a: &Mat<f64>,
    b: &Mat<f64>,
    c_emul: &Mat<f64>,
    rows: &[usize],
) -> Result<ProbeReport> {
    probe_with(
        a,
        b,
        c_emul,
        rows,
        |sub, b| dgemm_blocked(sub, b, &KernelConfig::single_threaded()),
        |x: f64| x.abs(),
        |x: f64, y: f64| (x - y).abs(),
    )
}

/// Complex twin of [`probe_dgemm`] ([`zgemm_blocked`], one thread).
pub fn probe_zgemm(a: &ZMat, b: &ZMat, c_emul: &ZMat, rows: &[usize]) -> Result<ProbeReport> {
    probe_with(
        a,
        b,
        c_emul,
        rows,
        |sub, b| zgemm_blocked(sub, b, &KernelConfig::single_threaded()),
        |x: crate::complex::c64| x.abs(),
        |x: crate::complex::c64, y: crate::complex::c64| (x - y).abs(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dgemm_naive;
    use crate::ozaki::ozaki_dgemm;
    use crate::testing::Rng as TRng;

    #[test]
    fn sample_rows_is_deterministic_distinct_and_bounded() {
        for m in [1usize, 2, 7, 40] {
            for want in [1usize, 2, 5, 64] {
                let a = sample_rows(42, m, want);
                let b = sample_rows(42, m, want);
                assert_eq!(a, b, "same seed must give the same rows");
                assert_eq!(a.len(), want.min(m));
                let mut dedup = a.clone();
                dedup.dedup();
                assert_eq!(dedup.len(), a.len(), "rows must be distinct: {a:?}");
                assert!(a.iter().all(|&r| r < m));
            }
        }
        assert!(sample_rows(1, 0, 3).is_empty());
        assert!(sample_rows(1, 5, 0).is_empty(), "want = 0 means no sampling");
        // different seeds eventually differ
        let x = sample_rows(1, 1000, 4);
        let y = sample_rows(2, 1000, 4);
        assert_ne!(x, y);
    }

    #[test]
    fn probe_reports_zero_for_exact_results() {
        let mut rng = TRng::new(7);
        let a = Mat::from_fn(12, 9, |_, _| rng.normal());
        let b = Mat::from_fn(9, 11, |_, _| rng.normal());
        let exact = dgemm_naive(&a, &b).unwrap();
        let rows = sample_rows(3, 12, 3);
        let rep = probe_dgemm(&a, &b, &exact, &rows).unwrap();
        // dgemm_blocked is bit-identical to dgemm_naive, so the probe of
        // an exact product must read exactly zero.
        assert_eq!(rep.rel_err, 0.0);
        assert_eq!(rep.rows, rows);
    }

    #[test]
    fn probe_sees_emulation_error() {
        let mut rng = TRng::new(8);
        let a = Mat::from_fn(16, 16, |_, _| rng.normal());
        let b = Mat::from_fn(16, 16, |_, _| rng.normal());
        let emul = ozaki_dgemm(&a, &b, 3).unwrap();
        let rows = sample_rows(5, 16, 4);
        let rep = probe_dgemm(&a, &b, &emul, &rows).unwrap();
        assert!(rep.rel_err > 1e-12, "3-split emulation error visible");
        assert!(rep.rel_err < 1e-2, "but small: {}", rep.rel_err);
    }

    #[test]
    fn probe_zgemm_matches_scale_of_real_probe() {
        let mut rng = TRng::new(9);
        let a = ZMat::from_fn(10, 8, |_, _| rng.cnormal());
        let b = ZMat::from_fn(8, 7, |_, _| rng.cnormal());
        let emul = crate::ozaki::ozaki_zgemm(&a, &b, 4).unwrap();
        let rows = sample_rows(11, 10, 2);
        let rep = probe_zgemm(&a, &b, &emul, &rows).unwrap();
        assert!(rep.rel_err > 0.0 && rep.rel_err < 1e-3, "{}", rep.rel_err);
    }

    #[test]
    fn probe_seed_separates_sites_and_ordinals() {
        let s1 = probe_seed("a.rs:1", 8, 8, 8, 0);
        let s2 = probe_seed("a.rs:2", 8, 8, 8, 0);
        let s3 = probe_seed("a.rs:1", 8, 8, 8, 1);
        assert_ne!(s1, s2);
        assert_ne!(s1, s3);
        assert_eq!(s1, probe_seed("a.rs:1", 8, 8, 8, 0));
    }
}
