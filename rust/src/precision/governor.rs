//! The precision governor: per-call-site split selection with a-priori
//! seeding, measured-residual calibration, and hysteresis.

use std::collections::HashMap;
use std::sync::Mutex;

use super::site_state::SiteState;
use super::{PrecisionConfig, PrecisionMode};
use crate::ozaki::{
    forward_error_bound_with, implied_constant, required_splits_in, ComputeMode,
};

/// Interned call-site key (the same `&'static str` ids the PEAK
/// profiler uses, see `crate::coordinator::CallSiteId`).
pub type SiteKey = &'static str;

/// Ceiling for the calibrated error-model constant (a wildly
/// pessimistic probe cannot pin a site to `max_splits` forever).
const CALIB_CEIL: f64 = 64.0;
/// Floor for the calibrated constant (an exactly-zero residual decays
/// toward this instead of 0, keeping the inverted bound meaningful).
const CALIB_FLOOR: f64 = 0.01;
/// Per-probe decay of the calibration's running max.
const CALIB_DECAY: f64 = 0.9;
/// Floor for the hysteresis goal: a probe compares against an FP64
/// reference whose own rounding is O(K·ε) ≈ 1e-12 relative for the
/// largest contractions we run, so demanding a measured residual below
/// this is asking the probe to see past its instrument.  Without the
/// floor, `target/κ` under extreme κ drops below FP64 resolution and
/// every probe "fails", pinning the site at `max_splits` with the
/// down-branch unreachable.  (The a-priori *model* seed is not floored
/// — bounds are analytic, not measured.)
const PROBE_MEASUREMENT_FLOOR: f64 = 1e-12;

/// One governed choice: the mode to execute and its split count.
///
/// `splits` is total (0 for native FP64), so callers never need the
/// partial match that used to hit `unreachable!()` in the old
/// `AdaptivePolicy::splits_for`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    /// Mode the call should execute in.
    pub mode: ComputeMode,
    /// Split count of that mode (0 when `mode` is [`ComputeMode::Dgemm`]).
    pub splits: u32,
}

impl Decision {
    /// Wrap an explicit mode (splits derived, total — no panic path).
    pub fn from_mode(mode: ComputeMode) -> Self {
        Decision {
            mode,
            splits: mode.splits().unwrap_or(0),
        }
    }
}

/// Read-only snapshot of one site's governor state (reports, tests).
#[derive(Clone, Debug)]
pub struct SiteSnapshot {
    /// Current split count (0 = never decided).
    pub splits: u32,
    /// Latest consumer κ fed to the site.
    pub kappa: f64,
    /// Calibrated error-model constant.
    pub calib: f64,
    /// Most recent probed residual.
    pub last_err: f64,
    /// Probes taken.
    pub probes: u64,
    /// Seconds spent probing.
    pub probe_s: f64,
    /// Split trajectory (consecutive duplicates collapsed).
    pub trajectory: Vec<u32>,
}

/// Feedback-driven per-call-site precision selection.
pub struct Governor {
    cfg: PrecisionConfig,
    sites: Mutex<HashMap<SiteKey, SiteState>>,
}

impl Governor {
    /// Build a governor for the given configuration.  The config is
    /// [normalized](PrecisionConfig::normalized) so the governor's
    /// arithmetic is total even for configurations built in code
    /// without `validate()`.
    pub fn new(cfg: PrecisionConfig) -> Self {
        Governor {
            cfg: cfg.normalized(),
            sites: Mutex::new(HashMap::new()),
        }
    }

    /// The configuration the governor runs under.
    pub fn config(&self) -> &PrecisionConfig {
        &self.cfg
    }

    /// A-priori split selection as a total function: the cheapest split
    /// count in the configured window whose bound meets the target
    /// under `kappa`, clamped to `max_splits` when the target is out of
    /// reach.  Never panics, never leaves `[min_splits, max_splits]`.
    pub fn splits_for(cfg: &PrecisionConfig, k_dim: usize, kappa: f64) -> (ComputeMode, u32) {
        let cfg = cfg.normalized();
        let s = seed_splits(&cfg, k_dim, kappa, crate::ozaki::DEFAULT_ERROR_CONSTANT);
        (ComputeMode::Int8 { splits: s }, s)
    }

    /// Governed mode for a call that *requested* `requested`: fixed
    /// mode and native-FP64 requests pass through untouched; emulated
    /// requests are retuned per site under apriori/feedback.
    pub fn apply(&self, site: SiteKey, requested: ComputeMode, k_dim: usize) -> Decision {
        match (self.cfg.mode, requested) {
            (PrecisionMode::Fixed, _) | (_, ComputeMode::Dgemm) => Decision::from_mode(requested),
            (_, ComputeMode::Int8 { .. }) => self.decide(site, k_dim, requested),
        }
    }

    /// Per-site *emulated* decision: always returns an Int8 mode in
    /// apriori/feedback (`fallback` is returned verbatim only in fixed
    /// mode).  Callers whose requested mode may be native FP64 and must
    /// pass through untouched go through [`Governor::apply`] instead —
    /// that is the seam both the dispatcher and the τ solver use.
    ///
    /// A site's effective contraction size is the *largest* `k_dim` it
    /// has seen: the error budget belongs to the consumer (e.g. a whole
    /// LU), so a small trailing-update GEMM re-entering the governor at
    /// the same site must not be granted fewer splits than the
    /// factorisation-level decision.
    pub fn decide(&self, site: SiteKey, k_dim: usize, fallback: ComputeMode) -> Decision {
        if self.cfg.mode == PrecisionMode::Fixed {
            return Decision::from_mode(fallback);
        }
        let mut sites = self.sites.lock().unwrap();
        let st = sites.entry(site).or_insert_with(SiteState::new);
        let k_eff = k_dim.max(st.k_dim);
        // Apriori re-derives on every decision; feedback holds its
        // probe-walked state once seeded — except when the site's
        // effective contraction size just grew, where the bound may now
        // demand more than the held count (same one-jump semantics as
        // the κ fast-attack; probes own the walk back down).
        let s = if self.cfg.mode.is_feedback_like() && st.splits != 0 {
            if k_eff > st.k_dim {
                st.splits
                    .max(seed_splits(&self.cfg, k_eff, st.kappa, st.calib))
            } else {
                st.splits
            }
        } else {
            seed_splits(&self.cfg, k_eff, st.kappa, st.calib)
        };
        st.splits = s;
        st.note_decision(s, k_eff);
        Decision {
            mode: ComputeMode::Int8 { splits: s },
            splits: s,
        }
    }

    /// Feed a measured consumer condition number (the LU/SCF seam).  In
    /// feedback mode a κ that demands more splits than the site is
    /// using raises them immediately (fast attack); walking back down
    /// is left to the probes (slow decay).
    pub fn feed_kappa(&self, site: SiteKey, kappa: f64) {
        if !kappa.is_finite() || kappa <= 0.0 {
            return;
        }
        if self.cfg.mode == PrecisionMode::Fixed {
            return;
        }
        let mut sites = self.sites.lock().unwrap();
        let st = sites.entry(site).or_insert_with(SiteState::new);
        st.kappa = kappa;
        if self.cfg.mode.is_feedback_like() && st.splits != 0 && st.k_dim != 0 {
            let seed = seed_splits(&self.cfg, st.k_dim, kappa, st.calib);
            if seed > st.splits {
                st.splits = seed;
                st.cooldown = self.cfg.cooldown;
            }
        }
    }

    /// Register one emulated call at `site`; returns the probe ordinal
    /// when this call should be probed (feedback mode: every
    /// `probe_period`-th call; certified mode: **every** call — the
    /// probe doubles as the a-posteriori certificate, so no call may
    /// skip it).  Under concurrent dispatch the ordinal assignment
    /// follows arrival order, like the rest of the per-site accounting.
    pub fn should_probe(&self, site: SiteKey) -> Option<u64> {
        if !self.cfg.mode.is_feedback_like() {
            return None;
        }
        let mut sites = self.sites.lock().unwrap();
        let st = sites.entry(site).or_insert_with(SiteState::new);
        let ord = st.emulated_calls;
        st.emulated_calls += 1;
        if self.cfg.mode == PrecisionMode::Certified || ord % self.cfg.probe_period as u64 == 0 {
            Some(ord)
        } else {
            None
        }
    }

    /// Close the loop with one probed residual: calibrate the error
    /// model from the measurement, then ramp the site's split count
    /// with hysteresis (up past `up_threshold·target/κ`, down below
    /// `down_threshold·target/κ` when the calibrated bound predicts the
    /// smaller count still meets the goal; `cooldown` probes must pass
    /// between adjustments).
    pub fn record_probe(&self, site: SiteKey, splits: u32, k_dim: usize, rel_err: f64, seconds: f64) {
        let mut sites = self.sites.lock().unwrap();
        let st = sites.entry(site).or_insert_with(SiteState::new);
        st.probes += 1;
        st.probe_s += seconds;
        if !rel_err.is_finite() || rel_err < 0.0 {
            return;
        }
        st.last_err = rel_err;
        if st.splits == 0 {
            // defensive seed for probes arriving before any decide():
            // adopt the probed call's parameters so the κ fast-attack
            // (which requires a known k_dim) works from the first feed
            st.splits = splits.clamp(self.cfg.min_splits, self.cfg.max_splits);
            st.k_dim = st.k_dim.max(k_dim);
        }
        if splits > 0 && k_dim > 0 {
            // Only calibrate when the model's per-unit-constant residual
            // at the probed split count is above the probe's FP64
            // resolution: below it the measurement is instrument noise
            // and would imply an absurd constant (clamped to the
            // ceiling, ratcheting calib up on every probe and stalling
            // the walk-down at high split counts).
            if forward_error_bound_with(1.0, splits, k_dim) > PROBE_MEASUREMENT_FLOOR {
                let c = implied_constant(rel_err, splits, k_dim);
                st.calib = (st.calib * CALIB_DECAY).max(c).clamp(CALIB_FLOOR, CALIB_CEIL);
            }
        }
        if !self.cfg.mode.is_feedback_like() {
            return;
        }
        // Hysteresis only acts on evidence gathered at the site's
        // *current* split count: under concurrent dispatch (or a κ
        // fast-attack between decision and probe) a stale measurement
        // must not step a state it was not taken at.  Calibration above
        // is exempt — it pairs the residual with the splits that
        // produced it.
        if splits != st.splits {
            return;
        }
        let goal = (self.cfg.target / st.kappa.max(1.0)).max(PROBE_MEASUREMENT_FLOOR);
        if st.cooldown > 0 {
            st.cooldown -= 1;
            return;
        }
        if rel_err > self.cfg.up_threshold * goal {
            if st.splits < self.cfg.max_splits {
                st.splits += 1;
                st.cooldown = self.cfg.cooldown;
            }
        } else if rel_err < self.cfg.down_threshold * goal && st.splits > self.cfg.min_splits {
            // predict at the site's consumer contraction size (the
            // largest k seen), not just the probed GEMM's — same
            // convention as the seeding path
            let k_pred = st.k_dim.max(k_dim).max(1);
            let predicted = forward_error_bound_with(st.calib, st.splits - 1, k_pred);
            if predicted <= goal {
                st.splits -= 1;
                st.cooldown = self.cfg.cooldown;
            }
        }
    }

    /// Record a certified-mode escalation: the site's split count jumps
    /// straight to `splits` (clamped to the configured window) so later
    /// calls start where the certificate forced this one, instead of
    /// re-failing and re-escalating from the old count.  A cooldown is
    /// set so the next good probe does not immediately walk it back.
    pub fn escalate(&self, site: SiteKey, splits: u32) {
        if self.cfg.mode == PrecisionMode::Fixed {
            return;
        }
        let mut sites = self.sites.lock().unwrap();
        let st = sites.entry(site).or_insert_with(SiteState::new);
        let s = splits.clamp(self.cfg.min_splits, self.cfg.max_splits).max(st.splits);
        if s != st.splits {
            st.splits = s;
            st.note_decision(s, st.k_dim);
        }
        st.cooldown = self.cfg.cooldown;
    }

    /// Snapshot one site's state, if it has been seen.
    pub fn snapshot(&self, site: SiteKey) -> Option<SiteSnapshot> {
        self.sites.lock().unwrap().get(site).map(snapshot_of)
    }

    /// Snapshot every governed site (sorted by key for stable output).
    pub fn snapshots(&self) -> Vec<(SiteKey, SiteSnapshot)> {
        let sites = self.sites.lock().unwrap();
        let mut out: Vec<(SiteKey, SiteSnapshot)> =
            sites.iter().map(|(k, v)| (*k, snapshot_of(v))).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// Drop all per-site state (e.g. between benchmark reps, mirroring
    /// `Dispatcher::reset_stats`).
    pub fn reset(&self) {
        self.sites.lock().unwrap().clear();
    }
}

fn snapshot_of(st: &SiteState) -> SiteSnapshot {
    SiteSnapshot {
        splits: st.splits,
        kappa: st.kappa,
        calib: st.calib,
        last_err: st.last_err,
        probes: st.probes,
        probe_s: st.probe_s,
        trajectory: st.trajectory.clone(),
    }
}

/// Smallest split count in `[cfg.min_splits, cfg.max_splits]` whose
/// calibrated bound meets the target under `kappa`, clamped to the
/// ceiling when the target is out of reach (total — never panics).
fn seed_splits(cfg: &PrecisionConfig, k_dim: usize, kappa: f64, calib: f64) -> u32 {
    required_splits_in(
        calib,
        cfg.target,
        k_dim.max(1),
        kappa,
        cfg.min_splits,
        cfg.max_splits,
    )
    .unwrap_or(cfg.max_splits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feedback_cfg() -> PrecisionConfig {
        PrecisionConfig {
            mode: PrecisionMode::Feedback,
            target: 1e-9,
            cooldown: 0,
            probe_period: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fixed_mode_passes_requests_through() {
        let g = Governor::new(PrecisionConfig::default());
        let req = ComputeMode::Int8 { splits: 6 };
        assert_eq!(g.apply("s", req, 256), Decision::from_mode(req));
        assert_eq!(
            g.apply("s", ComputeMode::Dgemm, 256),
            Decision::from_mode(ComputeMode::Dgemm)
        );
        assert!(g.should_probe("s").is_none());
    }

    #[test]
    fn dgemm_requests_never_governed() {
        let g = Governor::new(PrecisionConfig {
            mode: PrecisionMode::Feedback,
            ..Default::default()
        });
        let d = g.apply("s", ComputeMode::Dgemm, 256);
        assert_eq!(d.mode, ComputeMode::Dgemm);
        assert_eq!(d.splits, 0);
    }

    #[test]
    fn apriori_tracks_fed_kappa() {
        let g = Governor::new(PrecisionConfig {
            mode: PrecisionMode::Apriori,
            target: 1e-9,
            ..Default::default()
        });
        let low = g.decide("s", 256, ComputeMode::Dgemm).splits;
        g.feed_kappa("s", 1e8);
        let high = g.decide("s", 256, ComputeMode::Dgemm).splits;
        assert!(high > low, "{high} !> {low}");
    }

    #[test]
    fn feedback_ramps_up_on_bad_probes_and_down_on_good_ones() {
        // Loose enough target that the calibrated bound permits the
        // floor once the probes report clean residuals.
        let cfg = PrecisionConfig {
            target: 1e-4,
            ..feedback_cfg()
        };
        let g = Governor::new(cfg);
        let d0 = g.decide("s", 128, ComputeMode::Dgemm);
        // hammer with terrible residuals: must climb to the ceiling and stop
        for _ in 0..40 {
            let s = g.snapshot("s").unwrap().splits;
            g.record_probe("s", s, 128, 1.0, 0.0);
        }
        let up = g.snapshot("s").unwrap().splits;
        assert_eq!(up, cfg.max_splits);
        // now perfect residuals: must walk back down, never below the
        // floor (the calibration constant has to decay first, so give
        // it room)
        for _ in 0..120 {
            let s = g.snapshot("s").unwrap().splits;
            g.record_probe("s", s, 128, 0.0, 0.0);
        }
        let down = g.snapshot("s").unwrap().splits;
        assert_eq!(down, cfg.min_splits);
        assert!(d0.splits >= cfg.min_splits && d0.splits <= cfg.max_splits);
    }

    #[test]
    fn cooldown_throttles_adjustments() {
        let cfg = PrecisionConfig {
            cooldown: 3,
            ..feedback_cfg()
        };
        let g = Governor::new(cfg);
        let s0 = g.decide("s", 128, ComputeMode::Dgemm).splits;
        g.record_probe("s", s0, 128, 1.0, 0.0); // ramps, sets cooldown
        let s1 = g.snapshot("s").unwrap().splits;
        assert_eq!(s1, s0 + 1);
        for _ in 0..3 {
            g.record_probe("s", s1, 128, 1.0, 0.0); // cooldown swallows these
        }
        assert_eq!(g.snapshot("s").unwrap().splits, s1);
        g.record_probe("s", s1, 128, 1.0, 0.0); // cooldown expired
        assert_eq!(g.snapshot("s").unwrap().splits, s1 + 1);
    }

    #[test]
    fn kappa_fast_attack_raises_feedback_sites() {
        let g = Governor::new(feedback_cfg());
        let s0 = g.decide("s", 256, ComputeMode::Dgemm).splits;
        g.feed_kappa("s", 1e10);
        let s1 = g.snapshot("s").unwrap().splits;
        assert!(s1 > s0, "{s1} !> {s0}");
        // and a *smaller* κ does not lower it (probes own the decay)
        g.feed_kappa("s", 1.0);
        assert_eq!(g.snapshot("s").unwrap().splits, s1);
    }

    #[test]
    fn probe_cadence_follows_period() {
        let cfg = PrecisionConfig {
            probe_period: 3,
            ..feedback_cfg()
        };
        let g = Governor::new(cfg);
        let due: Vec<bool> = (0..7).map(|_| g.should_probe("s").is_some()).collect();
        assert_eq!(due, vec![true, false, false, true, false, false, true]);
    }

    #[test]
    fn splits_for_is_total_and_clamped() {
        let cfg = PrecisionConfig {
            target: 1e-300,
            min_splits: 4,
            max_splits: 9,
            ..Default::default()
        };
        let (mode, s) = Governor::splits_for(&cfg, 2048, 1e12);
        assert_eq!(s, 9);
        assert_eq!(mode, ComputeMode::Int8 { splits: 9 });
        let loose = PrecisionConfig {
            target: 1.0,
            min_splits: 5,
            max_splits: 9,
            ..Default::default()
        };
        assert_eq!(Governor::splits_for(&loose, 16, 1.0).1, 5);
    }

    #[test]
    fn certified_mode_probes_every_call() {
        let cfg = PrecisionConfig {
            mode: PrecisionMode::Certified,
            probe_period: 5, // the certificate must override the cadence
            ..feedback_cfg()
        };
        let g = Governor::new(cfg);
        assert!((0..9).all(|_| g.should_probe("s").is_some()));
    }

    #[test]
    fn escalate_jumps_and_never_lowers() {
        let g = Governor::new(PrecisionConfig {
            mode: PrecisionMode::Certified,
            ..feedback_cfg()
        });
        let s0 = g.decide("s", 64, ComputeMode::Dgemm).splits;
        g.escalate("s", s0 + 4);
        assert_eq!(g.snapshot("s").unwrap().splits, s0 + 4);
        g.escalate("s", s0); // lower request: state must hold
        assert_eq!(g.snapshot("s").unwrap().splits, s0 + 4);
        g.escalate("s", 99); // clamped to the window ceiling
        assert_eq!(g.snapshot("s").unwrap().splits, g.config().max_splits);
    }

    #[test]
    fn reset_clears_state() {
        let g = Governor::new(feedback_cfg());
        g.decide("s", 64, ComputeMode::Dgemm);
        assert!(g.snapshot("s").is_some());
        g.reset();
        assert!(g.snapshot("s").is_none());
        assert!(g.snapshots().is_empty());
    }
}
