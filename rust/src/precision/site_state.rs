//! Per-call-site governor state: the current split count, the latest
//! consumer κ, the measured-residual calibration of the error-model
//! constant, hysteresis bookkeeping, and the split trajectory the PEAK
//! report surfaces.

use crate::ozaki::DEFAULT_ERROR_CONSTANT;

/// Maximum trajectory entries retained per site (consecutive duplicates
/// are collapsed, so this bounds *changes*, not calls).
pub const TRAJECTORY_CAP: usize = 64;

/// Append one split decision to a trajectory vector: consecutive
/// duplicates collapse, and past [`TRAJECTORY_CAP`] retained changes
/// the *oldest* entry is evicted so the tail stays recent.  Shared by
/// the governor's [`SiteState`] and the PEAK profiler's per-site
/// statistics, so the two recorded trajectories cannot drift apart.
pub fn push_trajectory(trajectory: &mut Vec<u32>, splits: u32) {
    if trajectory.last() != Some(&splits) {
        if trajectory.len() == TRAJECTORY_CAP {
            trajectory.remove(0);
        }
        trajectory.push(splits);
    }
}

/// Mutable state the governor keeps per call site.
#[derive(Clone, Debug)]
pub struct SiteState {
    /// Current split count (0 = not yet seeded; feedback mode seeds it
    /// from the a-priori bound on first decision).
    pub splits: u32,
    /// Effective (largest-seen) contraction size of this site's
    /// decisions — the consumer's K, so small trailing-update GEMMs
    /// re-entering the governor share the factorisation-level budget
    /// (0 until the first decision; also used to re-seed when a larger
    /// κ is fed in).
    pub k_dim: usize,
    /// Latest consumer condition number fed to the governor.
    pub kappa: f64,
    /// Calibrated error-model constant: starts at the conservative
    /// a-priori default and tracks the measured residuals (running max
    /// with decay, so one quiet probe cannot collapse it).
    pub calib: f64,
    /// Most recent probed relative residual.
    pub last_err: f64,
    /// Probes to skip before the next split adjustment.
    pub cooldown: u32,
    /// Emulated calls seen at this site (drives the probe cadence).
    pub emulated_calls: u64,
    /// Probes taken at this site.
    pub probes: u64,
    /// Seconds spent probing at this site.
    pub probe_s: f64,
    /// Split counts decided at this site, consecutive duplicates
    /// collapsed, capped at [`TRAJECTORY_CAP`].
    pub trajectory: Vec<u32>,
}

impl Default for SiteState {
    fn default() -> Self {
        SiteState {
            splits: 0,
            k_dim: 0,
            kappa: 1.0,
            calib: DEFAULT_ERROR_CONSTANT,
            last_err: 0.0,
            cooldown: 0,
            emulated_calls: 0,
            probes: 0,
            probe_s: 0.0,
            trajectory: Vec::new(),
        }
    }
}

impl SiteState {
    /// Fresh state (κ = 1, calibration at the a-priori default).
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decided split count in the trajectory (see
    /// [`push_trajectory`] for the dedupe/eviction policy).
    pub fn note_decision(&mut self, splits: u32, k_dim: usize) {
        self.k_dim = k_dim;
        push_trajectory(&mut self.trajectory, splits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trajectory_dedupes_consecutive_and_caps() {
        let mut s = SiteState::new();
        for v in [6, 6, 6, 7, 7, 6] {
            s.note_decision(v, 64);
        }
        assert_eq!(s.trajectory, vec![6, 7, 6]);
        assert_eq!(s.k_dim, 64);
        for i in 0..(2 * TRAJECTORY_CAP as u32) {
            s.note_decision(3 + (i % 2), 64);
        }
        assert_eq!(s.trajectory.len(), TRAJECTORY_CAP);
        // overflow drops the *oldest* entries: the tail is the most
        // recent decision, not the initial history
        let last_pushed = 3 + ((2 * TRAJECTORY_CAP as u32 - 1) % 2);
        assert_eq!(s.trajectory.last(), Some(&last_pushed));
        assert_ne!(s.trajectory[0], 6, "initial history evicted");
    }

    #[test]
    fn defaults_are_unseeded() {
        let s = SiteState::new();
        assert_eq!(s.splits, 0);
        assert_eq!(s.kappa, 1.0);
        assert_eq!(s.calib, DEFAULT_ERROR_CONSTANT);
        assert!(s.trajectory.is_empty());
    }
}
