//! Panel packing: operands are laid out once into k-major tile panels
//! so the microkernels stream both inputs contiguously.
//!
//! A panel holds `tile` logical rows interleaved k-major: element
//! `(row, p)` of plane `s` lives at
//! `(s·tiles + row/tile)·k·tile + p·tile + row%tile`, so one microkernel
//! step reads `tile` consecutive values for consecutive rows at the same
//! `p` — the broadcast/vector shape LLVM autovectorizes.  Planes are
//! slice-major (all tiles of slice 0, then slice 1, ...), which is what
//! lets the fused Ozaki driver walk every retained slice pair over one
//! allocation.  Ragged edges are zero-padded; zero products are exact in
//! both integer and FP64 arithmetic, so padding never changes results.
//!
//! Packing parallelises over **whole-tile row blocks** on the
//! persistent worker pool ([`crate::runtime::pool`]): rows of the same
//! tile share a panel but different tiles never do, so tile-aligned
//! blocks write disjoint regions and the parallel packers emit the
//! exact bytes of their serial counterparts in any schedule.

use crate::complex::c64;
use crate::linalg::Mat;
use crate::runtime::pool::{self, SendPtr};

/// A layout-polymorphic read-only 2-D source for the packers: `rows`
/// logical rows of depth `k`, drawn from any constant-stride buffer.
/// Element `(r, p)` lives at `buf[r·row_stride + p·col_stride]`, which
/// covers every layout the packers meet — row-major matrices, their
/// column views, and raw **column-major** (Fortran/BLAS) buffers with a
/// leading-dimension stride — so a column-major operand packs directly
/// into panels instead of being copy-transposed into a row-major
/// matrix first.
#[derive(Clone, Copy, Debug)]
pub struct SrcView<'a, T> {
    buf: &'a [T],
    rows: usize,
    k: usize,
    row_stride: usize,
    col_stride: usize,
}

impl<'a, T: Copy> SrcView<'a, T> {
    /// Strided view with explicit geometry; `buf` must cover the last
    /// addressable element.
    pub fn strided(
        buf: &'a [T],
        rows: usize,
        k: usize,
        row_stride: usize,
        col_stride: usize,
    ) -> Self {
        if rows > 0 && k > 0 {
            let last = (rows - 1) * row_stride + (k - 1) * col_stride;
            assert!(last < buf.len(), "SrcView: buffer too short for geometry");
        }
        SrcView {
            buf,
            rows,
            k,
            row_stride,
            col_stride,
        }
    }

    /// The rows of a row-major matrix (A-side pack source).
    pub fn mat_rows(m: &'a Mat<T>) -> Self {
        SrcView {
            buf: m.data(),
            rows: m.rows(),
            k: m.cols(),
            row_stride: m.cols(),
            col_stride: 1,
        }
    }

    /// The columns of a row-major `k x n` matrix as logical rows
    /// (B-side pack source: packed row `j` is column `j`).
    pub fn mat_cols(m: &'a Mat<T>) -> Self {
        SrcView {
            buf: m.data(),
            rows: m.cols(),
            k: m.rows(),
            row_stride: 1,
            col_stride: m.cols(),
        }
    }

    /// The rows of a column-major `rows x k` buffer with leading
    /// dimension `ld >= rows` (element `(i, p)` at `buf[i + p·ld]`).
    pub fn colmajor_rows(buf: &'a [T], rows: usize, k: usize, ld: usize) -> Self {
        assert!(ld >= rows.max(1), "SrcView: ld < rows");
        Self::strided(buf, rows, k, 1, ld)
    }

    /// The columns of a column-major `k x n` buffer with leading
    /// dimension `ld >= k` as logical rows (element `(j, p)` at
    /// `buf[p + j·ld]`).
    pub fn colmajor_cols(buf: &'a [T], k: usize, n: usize, ld: usize) -> Self {
        assert!(ld >= k.max(1), "SrcView: ld < k");
        Self::strided(buf, n, k, ld, 1)
    }

    /// Logical rows of the view.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Depth (elements per logical row).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Element `(r, p)`.
    #[inline]
    pub fn at(&self, r: usize, p: usize) -> T {
        debug_assert!(r < self.rows && p < self.k);
        self.buf[r * self.row_stride + p * self.col_stride]
    }

    /// Materialise the view as an owned row-major matrix (the gather
    /// the dispatcher-facing adapters need; rows copy contiguously when
    /// `col_stride == 1`).
    pub fn to_mat(&self) -> Mat<T>
    where
        T: Default,
    {
        if self.col_stride == 1 {
            let mut out = Mat::zeros(self.rows, self.k);
            for r in 0..self.rows {
                let base = r * self.row_stride;
                out.row_mut(r).copy_from_slice(&self.buf[base..base + self.k]);
            }
            out
        } else {
            Mat::from_fn(self.rows, self.k, |r, p| self.at(r, p))
        }
    }

    /// Map the view element-wise into an owned row-major matrix
    /// (conjugating gathers for the complex `'C'` transpose flag).
    pub fn map_mat<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Mat<U> {
        Mat::from_fn(self.rows, self.k, |r, p| f(self.at(r, p)))
    }
}

/// Packed tile panels over `planes` slice planes of a `rows x k`
/// operand (`planes == 1` for plain FP64/complex-component GEMM).
#[derive(Clone, Debug)]
pub struct Panels<T> {
    data: Vec<T>,
    planes: usize,
    rows: usize,
    k: usize,
    tile: usize,
    tiles: usize,
}

/// The index geometry of a [`Panels`] buffer — a small `Copy` snapshot
/// the parallel packers close over so they can write through a raw
/// pointer without borrowing the `Panels` itself.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PanelLayout {
    tiles: usize,
    k: usize,
    tile: usize,
}

impl PanelLayout {
    /// Flat index of element `(row, p)` in plane `s` — the single
    /// source of truth for the panel layout.
    #[inline]
    pub(crate) fn index(&self, s: usize, row: usize, p: usize) -> usize {
        (s * self.tiles + row / self.tile) * (self.k * self.tile) + p * self.tile + row % self.tile
    }
}

impl<T: Copy + Default> Panels<T> {
    /// Zero-filled panels (`ceil(rows/tile)` tiles per plane).
    pub fn zeroed(planes: usize, rows: usize, k: usize, tile: usize) -> Self {
        assert!(tile > 0, "panel tile must be positive");
        let tiles = rows.div_ceil(tile);
        Panels {
            data: vec![T::default(); planes * tiles * k * tile],
            planes,
            rows,
            k,
            tile,
            tiles,
        }
    }

    /// Pack pre-sliced planes (each a `rows x k` row-major matrix).
    pub fn pack_planes(planes: &[Mat<T>], tile: usize) -> Self {
        let rows = planes.first().map(|m| m.rows()).unwrap_or(0);
        let k = planes.first().map(|m| m.cols()).unwrap_or(0);
        let mut out = Self::zeroed(planes.len(), rows, k, tile);
        for (s, plane) in planes.iter().enumerate() {
            assert!(
                plane.rows() == rows && plane.cols() == k,
                "pack_planes: ragged plane shapes"
            );
            for i in 0..rows {
                for (p, &v) in plane.row(i).iter().enumerate() {
                    out.set(s, i, p, v);
                }
            }
        }
        out
    }

    /// Number of slice planes packed (1 for plain FP64/complex GEMM).
    #[inline]
    pub fn planes(&self) -> usize {
        self.planes
    }

    /// Logical (unpadded) rows packed.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Contraction depth packed per panel.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical rows per tile (`MR`/`NR` of the consuming microkernel).
    #[inline]
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Number of tiles per plane.
    #[inline]
    pub fn tiles(&self) -> usize {
        self.tiles
    }

    /// Packed bytes (perf accounting for the bench JSON emitter and the
    /// panel cache's capacity bound).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<T>()
    }

    /// Index geometry snapshot for the parallel packers.
    #[inline]
    pub(crate) fn layout(&self) -> PanelLayout {
        PanelLayout {
            tiles: self.tiles,
            k: self.k,
            tile: self.tile,
        }
    }

    /// Base pointer for the parallel packers (writes must be disjoint).
    #[inline]
    pub(crate) fn as_mut_ptr(&mut self) -> *mut T {
        self.data.as_mut_ptr()
    }

    #[inline]
    fn panel_stride(&self) -> usize {
        self.k * self.tile
    }

    /// The k-major panel of tile `t` in plane `s`
    /// (length `k * tile`; `p`-th chunk of `tile` values is column `p`).
    #[inline]
    pub fn panel(&self, s: usize, t: usize) -> &[T] {
        let stride = self.panel_stride();
        let base = (s * self.tiles + t) * stride;
        &self.data[base..base + stride]
    }

    /// The contiguous `[k0, k1)` contraction window of panel `(s, t)` —
    /// the KC-resident slab the blocked drivers stream.  Because panels
    /// are k-major, a K window is a contiguous byte range; the drivers
    /// walk these windows with the KC loop outside the tile/slice-pair
    /// loops so one window's worth of panel data is reused while
    /// cache-hot instead of panels spanning the full K being re-read
    /// per output tile.
    #[inline]
    pub fn panel_window(&self, s: usize, t: usize, k0: usize, k1: usize) -> &[T] {
        debug_assert!(k0 <= k1 && k1 <= self.k);
        let stride = self.panel_stride();
        let base = (s * self.tiles + t) * stride;
        &self.data[base + k0 * self.tile..base + k1 * self.tile]
    }

    /// Write one element (used by the packers; zero-padding stays).
    #[inline]
    pub fn set(&mut self, s: usize, row: usize, p: usize, v: T) {
        debug_assert!(s < self.planes && row < self.rows && p < self.k);
        let idx = self.layout().index(s, row, p);
        self.data[idx] = v;
    }

    /// Read one element back (tests).
    #[inline]
    pub fn get(&self, s: usize, row: usize, p: usize) -> T {
        let idx = self.layout().index(s, row, p);
        self.data[idx]
    }
}

/// Run `fill(r0, r1)` over tile-aligned row blocks — serial when
/// `threads <= 1`, otherwise as tasks on the persistent worker pool.
/// Blocks cover whole tiles, so concurrent fills write disjoint panel
/// regions; results are identical to the serial order.
pub(crate) fn parallel_tile_rows<F>(rows: usize, tile: usize, threads: usize, fill: &F)
where
    F: Fn(usize, usize) + Sync,
{
    if rows == 0 {
        return;
    }
    let tiles = rows.div_ceil(tile);
    let threads = threads.max(1).min(tiles);
    if threads <= 1 {
        fill(0, rows);
        return;
    }
    let tiles_per_task = tiles.div_ceil(threads);
    let jobs = tiles.div_ceil(tiles_per_task);
    pool::run(jobs, threads, |j| {
        let r0 = j * tiles_per_task * tile;
        let r1 = ((j + 1) * tiles_per_task * tile).min(rows);
        fill(r0, r1);
    });
}

/// Pack any [`SrcView`] into one-plane panels, using up to `threads`
/// pool tasks — the single layout-polymorphic packing core every
/// layout-specific entry point below delegates to.  The loop order
/// follows the view's unit stride (row-contiguous sources stream rows,
/// column-contiguous sources stream depth), but the written bytes are
/// identical either way: writes land through [`PanelLayout::index`]
/// alone.
pub fn pack_view_mt<T: Copy + Default + Send + Sync>(
    src: SrcView<'_, T>,
    tile: usize,
    threads: usize,
) -> Panels<T> {
    let mut out = Panels::zeroed(1, src.rows(), src.k(), tile);
    let layout = out.layout();
    let ptr = SendPtr(out.as_mut_ptr());
    let k = src.k();
    parallel_tile_rows(src.rows(), tile, threads, &|r0, r1| {
        // Safety: row blocks are tile-aligned, hence disjoint.
        if src.col_stride == 1 {
            for r in r0..r1 {
                for p in 0..k {
                    unsafe { *ptr.get().add(layout.index(0, r, p)) = src.at(r, p) };
                }
            }
        } else {
            for p in 0..k {
                for r in r0..r1 {
                    unsafe { *ptr.get().add(layout.index(0, r, p)) = src.at(r, p) };
                }
            }
        }
    });
    out
}

/// Pack a complex [`SrcView`] into separate re/im one-plane panels
/// (the complex twin of [`pack_view_mt`]).
pub fn pack_view_c64_mt(
    src: SrcView<'_, c64>,
    tile: usize,
    threads: usize,
) -> (Panels<f64>, Panels<f64>) {
    let mut re = Panels::zeroed(1, src.rows(), src.k(), tile);
    let mut im = Panels::zeroed(1, src.rows(), src.k(), tile);
    let layout = re.layout();
    let ptr_re = SendPtr(re.as_mut_ptr());
    let ptr_im = SendPtr(im.as_mut_ptr());
    let k = src.k();
    parallel_tile_rows(src.rows(), tile, threads, &|r0, r1| {
        // Safety: row blocks are tile-aligned, hence disjoint.
        for r in r0..r1 {
            for p in 0..k {
                let z = src.at(r, p);
                let idx = layout.index(0, r, p);
                unsafe {
                    *ptr_re.get().add(idx) = z.re;
                    *ptr_im.get().add(idx) = z.im;
                }
            }
        }
    });
    (re, im)
}

/// Pack the rows of `a` (A-side operand) into one-plane panels, using
/// up to `threads` pool tasks.
pub fn pack_rows_f64_mt(a: &Mat<f64>, tile: usize, threads: usize) -> Panels<f64> {
    pack_view_mt(SrcView::mat_rows(a), tile, threads)
}

/// Pack the rows of `a` (A-side operand) into one-plane panels.
pub fn pack_rows_f64(a: &Mat<f64>, tile: usize) -> Panels<f64> {
    pack_rows_f64_mt(a, tile, 1)
}

/// Pack the columns of `b` (B-side operand, `k x n`) into one-plane
/// panels, using up to `threads` pool tasks: packed row `j` is column
/// `j` of `b`, and tasks split over tile blocks of `j`.
pub fn pack_cols_f64_mt(b: &Mat<f64>, tile: usize, threads: usize) -> Panels<f64> {
    pack_view_mt(SrcView::mat_cols(b), tile, threads)
}

/// Pack the columns of `b` (B-side operand, `k x n`) into one-plane
/// panels: packed row `j` is column `j` of `b`.
pub fn pack_cols_f64(b: &Mat<f64>, tile: usize) -> Panels<f64> {
    pack_cols_f64_mt(b, tile, 1)
}

/// Pack the rows of a complex matrix into separate re/im panels, using
/// up to `threads` pool tasks.
pub fn pack_rows_c64_mt(
    a: &crate::linalg::ZMat,
    tile: usize,
    threads: usize,
) -> (Panels<f64>, Panels<f64>) {
    pack_view_c64_mt(SrcView::mat_rows(a), tile, threads)
}

/// Pack the rows of a complex matrix into separate re/im panels.
pub fn pack_rows_c64(a: &crate::linalg::ZMat, tile: usize) -> (Panels<f64>, Panels<f64>) {
    pack_rows_c64_mt(a, tile, 1)
}

/// Pack the columns of a complex `k x n` matrix into re/im panels,
/// using up to `threads` pool tasks.
pub fn pack_cols_c64_mt(
    b: &crate::linalg::ZMat,
    tile: usize,
    threads: usize,
) -> (Panels<f64>, Panels<f64>) {
    pack_view_c64_mt(SrcView::mat_cols(b), tile, threads)
}

/// Pack the columns of a complex `k x n` matrix into re/im panels.
pub fn pack_cols_c64(b: &crate::linalg::ZMat, tile: usize) -> (Panels<f64>, Panels<f64>) {
    pack_cols_c64_mt(b, tile, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_layout_is_k_major() {
        // 3 rows, tile 2 -> 2 tiles, second padded with one zero row.
        let m = Mat::from_fn(3, 2, |i, j| (10 * i + j) as f64);
        let p = pack_rows_f64(&m, 2);
        assert_eq!(p.tiles(), 2);
        assert_eq!(p.panel(0, 0), &[0.0, 10.0, 1.0, 11.0]);
        assert_eq!(p.panel(0, 1), &[20.0, 0.0, 21.0, 0.0]);
    }

    #[test]
    fn panel_windows_tile_the_full_panel() {
        let m = Mat::from_fn(5, 7, |i, j| (i * 7 + j) as i8);
        let p = Panels::pack_planes(std::slice::from_ref(&m), 4);
        for (k0, k1) in [(0usize, 7usize), (0, 3), (3, 7), (2, 2), (6, 7)] {
            assert_eq!(p.panel_window(0, 0, k0, k1), &p.panel(0, 0)[k0 * 4..k1 * 4]);
            assert_eq!(p.panel_window(0, 1, k0, k1), &p.panel(0, 1)[k0 * 4..k1 * 4]);
        }
        // concatenating adjacent windows reproduces the whole panel
        let whole: Vec<i8> = [p.panel_window(0, 0, 0, 4), p.panel_window(0, 0, 4, 7)].concat();
        assert_eq!(whole.as_slice(), p.panel(0, 0));
    }

    #[test]
    fn col_pack_matches_transpose() {
        let b = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        let p = pack_cols_f64(&b, 4);
        for j in 0..5 {
            for k in 0..3 {
                assert_eq!(p.get(0, j, k), b.get(k, j));
            }
        }
    }

    #[test]
    fn pack_planes_roundtrips() {
        let a = Mat::from_fn(5, 7, |i, j| (i * 7 + j) as i8);
        let b = Mat::from_fn(5, 7, |i, j| -((i + j) as i8));
        let p = Panels::pack_planes(&[a.clone(), b.clone()], 4);
        assert_eq!(p.planes(), 2);
        for i in 0..5 {
            for j in 0..7 {
                assert_eq!(p.get(0, i, j), a.get(i, j));
                assert_eq!(p.get(1, i, j), b.get(i, j));
            }
        }
    }

    #[test]
    fn empty_operands_are_legal() {
        let p = Panels::<i8>::zeroed(3, 0, 4, 8);
        assert_eq!(p.tiles(), 0);
        assert_eq!(p.bytes(), 0);
        let q = pack_rows_f64(&Mat::zeros(2, 0), 4);
        assert_eq!(q.k(), 0);
        assert_eq!(q.panel(0, 0), &[] as &[f64]);
    }

    #[test]
    fn complex_pack_splits_components() {
        use crate::complex::c64;
        let z = Mat::from_fn(2, 3, |i, j| c64(i as f64, j as f64));
        let (re, im) = pack_rows_c64(&z, 2);
        assert_eq!(re.get(0, 1, 2), 1.0);
        assert_eq!(im.get(0, 1, 2), 2.0);
        let (bre, bim) = pack_cols_c64(&z, 2);
        assert_eq!(bre.get(0, 2, 1), 1.0);
        assert_eq!(bim.get(0, 2, 1), 2.0);
    }

    #[test]
    fn colmajor_views_pack_identically_to_rowmajor_copies() {
        use crate::complex::c64;
        // A 5x4 logical matrix stored column-major with ld = 7 (padded).
        let (rows, k, ld) = (5usize, 4usize, 7usize);
        let mut cm = vec![f64::NAN; ld * k]; // padding rows poisoned
        let m = Mat::from_fn(rows, k, |i, p| (i * 31 + p) as f64 * 0.5 - 3.0);
        for p in 0..k {
            for i in 0..rows {
                cm[i + p * ld] = m.get(i, p);
            }
        }
        for threads in [1usize, 3] {
            // A-side: column-major rows view ≡ packing the row-major copy.
            let via_view = pack_view_mt(SrcView::colmajor_rows(&cm, rows, k, ld), 2, threads);
            let via_mat = pack_rows_f64_mt(&m, 2, threads);
            for i in 0..rows {
                for p in 0..k {
                    assert_eq!(via_view.get(0, i, p), via_mat.get(0, i, p));
                }
            }
            // B-side: the same buffer read as a k x n column-major operand
            // (k = 5 depth, n = 4 columns) ≡ packing the transposed copy's
            // columns.
            let bt = Mat::from_fn(rows, k, |i, p| cm[i + p * ld]);
            let via_cols = pack_view_mt(SrcView::colmajor_cols(&cm, rows, k, ld), 2, threads);
            let via_tmat = pack_cols_f64_mt(&bt, 2, threads);
            for j in 0..k {
                for p in 0..rows {
                    assert_eq!(via_cols.get(0, j, p), via_tmat.get(0, j, p));
                }
            }
        }
        // Complex twin through the same strided geometry.
        let zm = Mat::from_fn(rows, k, |i, p| c64(i as f64 + 0.25, -(p as f64)));
        let mut zcm = vec![c64(f64::NAN, f64::NAN); ld * k];
        for p in 0..k {
            for i in 0..rows {
                zcm[i + p * ld] = zm.get(i, p);
            }
        }
        let (vre, vim) = pack_view_c64_mt(SrcView::colmajor_rows(&zcm, rows, k, ld), 2, 2);
        let (mre, mim) = pack_rows_c64_mt(&zm, 2, 2);
        for i in 0..rows {
            for p in 0..k {
                assert_eq!(vre.get(0, i, p), mre.get(0, i, p));
                assert_eq!(vim.get(0, i, p), mim.get(0, i, p));
            }
        }
    }

    #[test]
    fn srcview_materialisers_gather_all_layouts() {
        use crate::complex::c64;
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        // mat_rows round-trips; mat_cols materialises the transpose.
        assert_eq!(SrcView::mat_rows(&m).to_mat().data(), m.data());
        assert_eq!(SrcView::mat_cols(&m).to_mat().data(), m.transposed().data());
        // Column-major buffer with padding gathers the logical matrix.
        let (rows, k, ld) = (3usize, 4usize, 5usize);
        let mut cm = vec![-1.0f64; ld * k];
        for p in 0..k {
            for i in 0..rows {
                cm[i + p * ld] = m.get(i, p);
            }
        }
        assert_eq!(SrcView::colmajor_rows(&cm, rows, k, ld).to_mat().data(), m.data());
        // map_mat applies the element transform (conjugating gather).
        let z = Mat::from_fn(2, 2, |i, j| c64(i as f64, j as f64 + 1.0));
        let conj = SrcView::mat_rows(&z).map_mat(|v| c64(v.re, -v.im));
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(conj.get(i, j), c64(i as f64, -(j as f64 + 1.0)));
            }
        }
    }

    #[test]
    fn parallel_packers_match_serial_bytes() {
        use crate::complex::c64;
        let a = Mat::from_fn(13, 9, |i, j| (i * 100 + j) as f64 * 0.25);
        let z = Mat::from_fn(13, 9, |i, j| c64(i as f64, -(j as f64)));
        for threads in [2usize, 3, 8] {
            let s = pack_rows_f64(&a, 4);
            let p = pack_rows_f64_mt(&a, 4, threads);
            for i in 0..13 {
                for q in 0..9 {
                    assert_eq!(p.get(0, i, q), s.get(0, i, q), "rows t={threads}");
                }
            }
            let sc = pack_cols_f64(&a, 8);
            let pc = pack_cols_f64_mt(&a, 8, threads);
            for j in 0..9 {
                for q in 0..13 {
                    assert_eq!(pc.get(0, j, q), sc.get(0, j, q), "cols t={threads}");
                }
            }
            let (sre, sim) = pack_rows_c64(&z, 2);
            let (pre, pim) = pack_rows_c64_mt(&z, 2, threads);
            let (scr, sci) = pack_cols_c64(&z, 4);
            let (pcr, pci) = pack_cols_c64_mt(&z, 4, threads);
            for i in 0..13 {
                for q in 0..9 {
                    assert_eq!(pre.get(0, i, q), sre.get(0, i, q));
                    assert_eq!(pim.get(0, i, q), sim.get(0, i, q));
                }
            }
            for j in 0..9 {
                for q in 0..13 {
                    assert_eq!(pcr.get(0, j, q), scr.get(0, j, q));
                    assert_eq!(pci.get(0, j, q), sci.get(0, j, q));
                }
            }
        }
    }
}
