//! Blocked INT8→INT32 GEMM core and the fused multi-slice driver of the
//! host Ozaki path.
//!
//! The microkernel computes an `MR_I8 x NR_I8` register tile: per `p` it
//! broadcasts `MR_I8` packed A values against `NR_I8` packed B values —
//! the dp4a-style shape (SNIPPETS.md §1).  On the exact-`i32` fast path
//! the tile body is **runtime-dispatched** through
//! [`super::simd`]: explicit AVX2/AVX-512/NEON kernels when the machine
//! has them (`KernelConfig::simd`, `run.simd`/`OZACCEL_SIMD`), the
//! scalar/autovectorized body otherwise.  The scalar generic serves
//! both accumulator widths through the crate-private `Accum` trait
//! (`i32` oracle,
//! `i64` past the overflow bound), so the escape path can
//! never drift from the fast one.  The fused driver sweeps the packed
//! panels once per output tile and accumulates *every* retained slice
//! pair `k + l = d < splits` while the tile's operands are cache-hot,
//! replacing the seed's `splits·(splits+1)/2` full-matrix passes with
//! one pass and zero heap allocations in the hot loop (the EmuGEMM
//! fusion idea, PAPERS.md).
//!
//! Exactness: each anti-diagonal's products are summed in `i32`, which
//! is exact while `(d+1)·K·127² < 2³¹` (`K·(d+1) <=`
//! [`MAX_EXACT_I32_TERMS`]).  Past that bound the driver falls back to
//! `i64` accumulators — still exact, never silently wrapping.  The FP64
//! combine then adds diagonals in ascending-`d` order per element, so
//! results are bit-for-bit identical to the reference slice-pair-major
//! path and the AOT'd HLO graph regardless of tiling or thread count.
//! Row bands execute on the persistent worker pool through
//! [`super::run_bands`].

use super::pack::Panels;
use super::simd::Microkernel;
use super::{run_bands, KernelConfig};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::runtime::pool::{self, SendPtr};

/// Rows per A-side register tile.
pub const MR_I8: usize = 4;
/// Columns per B-side register tile.
pub const NR_I8: usize = 8;
/// Columns per B-side register tile in the **wide** (AVX-512
/// native-width) variant the shape autotuner can select
/// (`KernelConfig::nr = 16`).  B panels are packed with this tile width
/// and the fused sweep runs [`super::simd::Microkernel::run_wide`];
/// exact integer accumulation keeps the choice invisible in the result
/// bits, so it is purely a throughput knob.
pub const NR_I8_WIDE: usize = 16;

/// Maximum number of `i8·i8` product terms an `i32` accumulator can
/// absorb exactly in the worst case (`|q| <= 127`):
/// `floor((2³¹−1) / 127²) = 133_144`.
pub const MAX_EXACT_I32_TERMS: usize = (i32::MAX as usize) / (127 * 127);

/// Whether a fused sweep over contraction size `k` with `splits` slices
/// must take the `i64` wide-accumulator escape (worst-case terms per
/// anti-diagonal accumulator: `K·splits`).  The single home of the
/// predicate — the sweep drivers and the PEAK `wide` counter both
/// consult it, so the report can never disagree with the kernel.
pub fn is_wide(k: usize, splits: u32) -> bool {
    k.saturating_mul(splits as usize) > MAX_EXACT_I32_TERMS
}

/// Integer accumulator of the INT8 microkernel: `i32` while the term
/// count stays under [`MAX_EXACT_I32_TERMS`], `i64` beyond.  Both
/// widths share one microkernel and one diagonal-accumulation body, so
/// the overflow-escape path is the same code as the fast path.
pub(crate) trait Accum: Copy + Default {
    fn from_i8(v: i8) -> Self;
    /// `self + a·b`, exact in the accumulator's range.
    fn mul_acc(self, a: Self, b: Self) -> Self;
    fn to_f64(self) -> f64;
}

impl Accum for i32 {
    #[inline(always)]
    fn from_i8(v: i8) -> Self {
        v as i32
    }
    #[inline(always)]
    fn mul_acc(self, a: Self, b: Self) -> Self {
        self + a * b
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Accum for i64 {
    #[inline(always)]
    fn from_i8(v: i8) -> Self {
        v as i64
    }
    #[inline(always)]
    fn mul_acc(self, a: Self, b: Self) -> Self {
        self + a * b
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// The scalar/autovectorized microkernel body — the oracle the
/// explicit-SIMD kernels in [`super::simd`] are pinned against, and the
/// only body the rare `i64` wide-accumulator escape runs.
#[inline]
pub(crate) fn microkernel<A: Accum>(acc: &mut [[A; NR_I8]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
    microkernel_nr::<A, NR_I8>(acc, a_panel, b_panel);
}

/// [`microkernel`] generalized over the B-tile width: the same scalar
/// body serves the classic [`NR_I8`] tile and the [`NR_I8_WIDE`] NR=16
/// tile (and both accumulator widths), so no tile variant can drift
/// from the oracle.
#[inline]
pub(crate) fn microkernel_nr<A: Accum, const NR: usize>(
    acc: &mut [[A; NR]; MR_I8],
    a_panel: &[i8],
    b_panel: &[i8],
) {
    for (av, bv) in a_panel.chunks_exact(MR_I8).zip(b_panel.chunks_exact(NR)) {
        for r in 0..MR_I8 {
            let ar = A::from_i8(av[r]);
            let row = &mut acc[r];
            for c in 0..NR {
                row[c] = row[c].mul_acc(ar, A::from_i8(bv[c]));
            }
        }
    }
}

/// Accumulate one anti-diagonal `d` of the fused sweep into `ctile`:
/// `ctile += w · Σ_{kk=0..=d} A_kk · B_{d−kk}ᵀ` for the `(it, jt)`
/// output tile, summed exactly in the integer accumulator `A` by the
/// given tile runner (`run` is the selected SIMD microkernel on the
/// `i32` path, the scalar generic on the `i64` wide escape — one body
/// serves both widths, so the escape path cannot drift from the fast
/// one).
///
/// The KC block loop runs **outside** the slice-pair loop, so all
/// `d+1` plane pairs stream the same `[k0, k1)` panel windows while
/// they are cache-hot (KC-resident streaming on large-K GEMMs);
/// integer accumulation is exact, so this reordering — like the ISA
/// choice — cannot change a single bit.
#[inline]
#[allow(clippy::too_many_arguments)]
fn accumulate_diagonal<A: Accum, const NR: usize>(
    ctile: &mut [[f64; NR]; MR_I8],
    d: usize,
    w: f64,
    a_tile: usize,
    jt: usize,
    ap: &Panels<i8>,
    bp: &Panels<i8>,
    kc: usize,
    run: &dyn Fn(&mut [[A; NR]; MR_I8], &[i8], &[i8]),
) {
    let k = ap.k();
    let mut acc = [[A::default(); NR]; MR_I8];
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + kc).min(k);
        for kk in 0..=d {
            run(
                &mut acc,
                ap.panel_window(kk, a_tile, k0, k1),
                bp.panel_window(d - kk, jt, k0, k1),
            );
        }
        k0 = k1;
    }
    for r in 0..MR_I8 {
        for cc in 0..NR {
            ctile[r][cc] += acc[r][cc].to_f64() * w;
        }
    }
}

/// Fused multi-slice sweep: `C = Σ_d weights[d] · D_d` with
/// `D_d = Σ_{k+l=d} A_k · B_lᵀ`, one pass over the packed panels.
///
/// `ap` must be packed with tile [`MR_I8`], `bp` with [`NR_I8`] or
/// [`NR_I8_WIDE`], and `weights.len()` selects how many anti-diagonals
/// are retained (the
/// ozIMMU triangle keeps `d < splits`).  Row bands are distributed over
/// `cfg.threads` tasks on the persistent worker pool; the result is
/// independent of the thread count.
pub fn fused_ozaki_sweep(
    ap: &Panels<i8>,
    bp: &Panels<i8>,
    weights: &[f64],
    cfg: &KernelConfig,
) -> Result<Mat<f64>> {
    check_sweep(ap, bp, weights)?;
    crate::faults::maybe_fail(crate::faults::FaultSite::SliceOverflow, Error::Numerical)?;
    let (m, n) = (ap.rows(), bp.rows());
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 || weights.is_empty() {
        return Ok(c);
    }
    let wide = is_wide(ap.k(), weights.len() as u32);
    let mk = cfg.simd.resolve().microkernel();

    run_bands(
        c.data_mut(),
        n,
        MR_I8,
        ap.tiles(),
        cfg.threads,
        |band, tile0| fused_band(band, tile0, n, ap, bp, weights, cfg, wide, mk),
    );
    Ok(c)
}

/// One member of a [`fused_ozaki_sweep_many`] batch: a packed operand
/// pair plus its retained anti-diagonal weights.  Each member computes
/// exactly what [`fused_ozaki_sweep`] would for the same inputs.
#[derive(Clone, Copy)]
pub struct SweepSpec<'a> {
    /// A-side panels (packed with [`MR_I8`]).
    pub ap: &'a Panels<i8>,
    /// B-side panels (packed with [`NR_I8`] or [`NR_I8_WIDE`]).
    pub bp: &'a Panels<i8>,
    /// Anti-diagonal weights (`d < splits` retained).
    pub weights: &'a [f64],
}

/// Validate one sweep's panel pair (shared by the single and batched
/// entry points so their rejections cannot drift).
fn check_sweep(ap: &Panels<i8>, bp: &Panels<i8>, weights: &[f64]) -> Result<()> {
    if ap.tile() != MR_I8 || !(bp.tile() == NR_I8 || bp.tile() == NR_I8_WIDE) {
        return Err(Error::Shape(format!(
            "fused_ozaki_sweep: panels must be packed with tiles \
             {MR_I8}/{NR_I8} or {MR_I8}/{NR_I8_WIDE}, got {}/{}",
            ap.tile(),
            bp.tile()
        )));
    }
    if ap.k() != bp.k() {
        return Err(Error::Shape(format!(
            "fused_ozaki_sweep: contraction mismatch {} vs {}",
            ap.k(),
            bp.k()
        )));
    }
    if ap.planes() != bp.planes() || weights.len() > ap.planes() {
        return Err(Error::Shape(format!(
            "fused_ozaki_sweep: {} A-planes, {} B-planes, {} weights",
            ap.planes(),
            bp.planes(),
            weights.len()
        )));
    }
    Ok(())
}

/// The multi-C fused driver: run many independent Ozaki sweeps as **one**
/// scheduling unit on the persistent worker pool — the batch engine's
/// ([`crate::engine`]) kernel entry point.
///
/// Every member's row bands are cut exactly as [`fused_ozaki_sweep`]
/// would cut them for `cfg.threads` (the partition depends only on the
/// member's own shape and the configured thread count, never on the
/// batch size), and each band computes the same pure function of its
/// packed inputs — so each returned matrix is **bit-for-bit identical**
/// to a standalone `fused_ozaki_sweep` call on the same panels.  The
/// batching win is scheduling, not math: all members' bands enter one
/// `pool::run`, so a bucket of small GEMMs saturates the pool (members
/// × bands tasks) instead of paying one dispatch-and-latch round trip
/// per call, and shared packed operands stay hot across consecutive
/// members.
///
/// Validation is all-or-nothing: if any member's panels are malformed,
/// the whole batch is rejected before any compute runs.  A member whose
/// band *panics* mid-run fails the whole batch too (the panic payload
/// becomes the error) — callers that need per-member isolation use
/// [`fused_ozaki_sweep_many_isolated`].
pub fn fused_ozaki_sweep_many(
    jobs: &[SweepSpec<'_>],
    cfg: &KernelConfig,
) -> Result<Vec<Mat<f64>>> {
    fused_ozaki_sweep_many_isolated(jobs, cfg)?
        .into_iter()
        .collect()
}

/// [`fused_ozaki_sweep_many`] with **per-member failure domains**: the
/// batch engine's chaos-hardened entry point.
///
/// Each member's band tasks run wrapped in `catch_unwind`, so a
/// panicking band (a kernel bug, or an injected
/// [`crate::faults::FaultSite::WorkerPanic`]) marks only its *owning
/// member* failed — every other member's result is computed exactly as
/// a standalone [`fused_ozaki_sweep`] would, bit for bit, and the
/// worker pool and panel cache stay unpoisoned (the pool's own
/// re-raise never sees a caught panic).  The outer `Result` still
/// rejects malformed batches all-or-nothing, before any compute runs.
pub fn fused_ozaki_sweep_many_isolated(
    jobs: &[SweepSpec<'_>],
    cfg: &KernelConfig,
) -> Result<Vec<Result<Mat<f64>>>> {
    for spec in jobs {
        check_sweep(spec.ap, spec.bp, spec.weights)?;
    }
    let mut outs: Vec<Mat<f64>> = jobs
        .iter()
        .map(|s| Mat::zeros(s.ap.rows(), s.bp.rows()))
        .collect();
    if jobs.is_empty() {
        return Ok(Vec::new());
    }
    let mk = cfg.simd.resolve().microkernel();

    // Flat (member, band) task list, each band addressed by its byte
    // range in the member's output — the same cuts `run_bands` makes.
    struct BandTask {
        job: usize,
        start: usize,
        end: usize,
        tile0: usize,
    }
    let mut tasks: Vec<BandTask> = Vec::new();
    for (ji, spec) in jobs.iter().enumerate() {
        let (m, n) = (spec.ap.rows(), spec.bp.rows());
        if m == 0 || n == 0 || spec.weights.is_empty() {
            continue;
        }
        // The same cuts `run_bands` makes — `band_ranges` is the one
        // home of the partition arithmetic, so the per-call and batched
        // drivers cannot drift.
        for (start, end, tile0) in super::band_ranges(m * n, n, MR_I8, spec.ap.tiles(), cfg.threads)
        {
            tasks.push(BandTask {
                job: ji,
                start,
                end,
                tile0,
            });
        }
    }
    let bases: Vec<SendPtr<f64>> = outs
        .iter_mut()
        .map(|c| SendPtr(c.data_mut().as_mut_ptr()))
        .collect();
    // One failure slot per member: the first panicking band of a member
    // records its payload; bucket-mates never observe it.
    let failed: Vec<std::sync::Mutex<Option<String>>> =
        jobs.iter().map(|_| std::sync::Mutex::new(None)).collect();
    pool::run(tasks.len(), cfg.threads.max(1), |ti| {
        let t = &tasks[ti];
        let spec = &jobs[t.job];
        let n = spec.bp.rows();
        let wide = is_wide(spec.ap.k(), spec.weights.len() as u32);
        // Safety: tasks of one job are disjoint in-bounds subslices of
        // that job's output; distinct jobs write distinct matrices.
        let slice =
            unsafe { std::slice::from_raw_parts_mut(bases[t.job].get().add(t.start), t.end - t.start) };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::faults::maybe_panic(crate::faults::FaultSite::WorkerPanic);
            fused_band(slice, t.tile0, n, spec.ap, spec.bp, spec.weights, cfg, wide, mk);
        }));
        if let Err(payload) = r {
            let msg = panic_message(payload.as_ref());
            let mut slot = failed[t.job].lock().unwrap();
            if slot.is_none() {
                *slot = Some(msg);
            }
        }
    });
    Ok(outs
        .into_iter()
        .zip(failed)
        .map(|(c, f)| match f.into_inner().unwrap() {
            None => Ok(c),
            Some(msg) => Err(Error::Numerical(format!(
                "fused sweep band panicked: {msg}"
            ))),
        })
        .collect())
}

/// Render a caught panic payload (the two shapes `panic!` produces).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One row band of the fused sweep.  `c_band` covers whole tiles
/// (bands are multiples of `MR_I8` rows except the ragged tail).
/// Dispatches on the B panels' tile width: the classic NR=8 tile runs
/// [`Microkernel::run`], the NR=16 wide tile
/// [`Microkernel::run_wide`] — one generic body serves both, and exact
/// integer accumulation keeps the choice bit-invisible.
#[allow(clippy::too_many_arguments)]
fn fused_band(
    c_band: &mut [f64],
    tile0: usize,
    n: usize,
    ap: &Panels<i8>,
    bp: &Panels<i8>,
    weights: &[f64],
    cfg: &KernelConfig,
    wide: bool,
    mk: &dyn Microkernel,
) {
    match bp.tile() {
        NR_I8 => fused_band_nr::<NR_I8>(
            c_band,
            tile0,
            n,
            ap,
            bp,
            weights,
            cfg,
            wide,
            &|acc, a, b| mk.run(acc, a, b),
        ),
        NR_I8_WIDE => fused_band_nr::<NR_I8_WIDE>(
            c_band,
            tile0,
            n,
            ap,
            bp,
            weights,
            cfg,
            wide,
            &|acc, a, b| mk.run_wide(acc, a, b),
        ),
        other => unreachable!("check_sweep admits only NR {NR_I8}/{NR_I8_WIDE}, got {other}"),
    }
}

/// The NR-generic band body behind [`fused_band`].
#[allow(clippy::too_many_arguments)]
fn fused_band_nr<const NR: usize>(
    c_band: &mut [f64],
    tile0: usize,
    n: usize,
    ap: &Panels<i8>,
    bp: &Panels<i8>,
    weights: &[f64],
    cfg: &KernelConfig,
    wide: bool,
    run32: &dyn Fn(&mut [[i32; NR]; MR_I8], &[i8], &[i8]),
) {
    let band_rows = c_band.len() / n;
    let band_tiles = band_rows.div_ceil(MR_I8);
    let kc = cfg.kc.max(1);
    let mc_tiles = (cfg.mc / MR_I8).max(1);
    let nc_tiles = (cfg.nc / NR).max(1);
    let n_tiles = bp.tiles();

    for ic in (0..band_tiles).step_by(mc_tiles) {
        let ic_end = (ic + mc_tiles).min(band_tiles);
        for jc in (0..n_tiles).step_by(nc_tiles) {
            let jc_end = (jc + nc_tiles).min(n_tiles);
            for it in ic..ic_end {
                let row0 = it * MR_I8;
                let ilim = MR_I8.min(band_rows - row0);
                for jt in jc..jc_end {
                    let col0 = jt * NR;
                    let jlim = NR.min(n - col0);
                    let mut ctile = [[0.0f64; NR]; MR_I8];
                    for (d, &w) in weights.iter().enumerate() {
                        if wide {
                            accumulate_diagonal::<i64, NR>(
                                &mut ctile,
                                d,
                                w,
                                tile0 + it,
                                jt,
                                ap,
                                bp,
                                kc,
                                &|acc, a, b| microkernel_nr::<i64, NR>(acc, a, b),
                            );
                        } else {
                            accumulate_diagonal::<i32, NR>(
                                &mut ctile,
                                d,
                                w,
                                tile0 + it,
                                jt,
                                ap,
                                bp,
                                kc,
                                run32,
                            );
                        }
                    }
                    for r in 0..ilim {
                        let base = (row0 + r) * n + col0;
                        for (dst, src) in c_band[base..base + jlim].iter_mut().zip(&ctile[r]) {
                            *dst = *src;
                        }
                    }
                }
            }
        }
    }
}

/// Blocked, threaded INT8 GEMM with exact `i32` accumulation:
/// `a (M×K) · bt (N×K)ᵀ` on the packed microkernel — the single-slice
/// entry point, bit-for-bit equal to [`crate::ozaki::int8_gemm_i32`].
pub fn int8_gemm_blocked(a: &Mat<i8>, bt: &Mat<i8>, cfg: &KernelConfig) -> Result<Mat<i32>> {
    if a.cols() != bt.cols() {
        return Err(Error::Shape(format!(
            "int8_gemm_blocked: {}x{} · ({}x{})ᵀ",
            a.rows(),
            a.cols(),
            bt.rows(),
            bt.cols()
        )));
    }
    if a.cols() > MAX_EXACT_I32_TERMS {
        return Err(Error::Numerical(format!(
            "int8_gemm_blocked: K={} may overflow the i32 accumulator \
             (exact bound K <= {MAX_EXACT_I32_TERMS})",
            a.cols()
        )));
    }
    let (m, n) = (a.rows(), bt.rows());
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return Ok(c);
    }
    let ap = Panels::pack_planes(std::slice::from_ref(a), MR_I8);
    let bp = Panels::pack_planes(std::slice::from_ref(bt), NR_I8);
    let mk = cfg.simd.resolve().microkernel();

    run_bands(
        c.data_mut(),
        n,
        MR_I8,
        ap.tiles(),
        cfg.threads,
        |band, tile0| int8_band(band, tile0, n, &ap, &bp, cfg, mk),
    );
    Ok(c)
}

/// One row band of the single-slice INT8 GEMM.
///
/// The KC block loop sits **outside** the tile loops: for each `[k0,
/// k1)` contraction window the band revisits every output tile and
/// adds the window's partial products into `c_band`, so the B-side
/// slab of the current `jc` block (`nc_tiles · kc · NR_I8` bytes)
/// stays cache-resident across all row tiles instead of the full-K
/// panels being re-streamed from memory per tile.  Partial sums land
/// directly in the `i32` output — exact, so the KC blocking (like
/// threads and ISA) is invisible in the result bits.
#[allow(clippy::too_many_arguments)]
fn int8_band(
    c_band: &mut [i32],
    tile0: usize,
    n: usize,
    ap: &Panels<i8>,
    bp: &Panels<i8>,
    cfg: &KernelConfig,
    mk: &dyn Microkernel,
) {
    let band_rows = c_band.len() / n;
    let band_tiles = band_rows.div_ceil(MR_I8);
    let k = ap.k();
    let kc = cfg.kc.max(1);
    let nc_tiles = (cfg.nc / NR_I8).max(1);
    let n_tiles = bp.tiles();

    for jc in (0..n_tiles).step_by(nc_tiles) {
        let jc_end = (jc + nc_tiles).min(n_tiles);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + kc).min(k);
            for it in 0..band_tiles {
                let row0 = it * MR_I8;
                let ilim = MR_I8.min(band_rows - row0);
                let awin = ap.panel_window(0, tile0 + it, k0, k1);
                for jt in jc..jc_end {
                    let col0 = jt * NR_I8;
                    let jlim = NR_I8.min(n - col0);
                    let mut acc = [[0i32; NR_I8]; MR_I8];
                    mk.run(&mut acc, awin, bp.panel_window(0, jt, k0, k1));
                    for r in 0..ilim {
                        let base = (row0 + r) * n + col0;
                        for (dst, src) in c_band[base..base + jlim].iter_mut().zip(&acc[r]) {
                            *dst += *src;
                        }
                    }
                }
            }
            k0 = k1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{available_isas, SimdSelect};
    use crate::testing::Rng;

    fn rand_i8(rng: &mut Rng, r: usize, c: usize) -> Mat<i8> {
        Mat::from_fn(r, c, |_, _| (rng.index(0, 255) as i32 - 127) as i8)
    }

    fn naive_i32(a: &Mat<i8>, bt: &Mat<i8>) -> Mat<i32> {
        Mat::from_fn(a.rows(), bt.rows(), |i, j| {
            a.row(i)
                .iter()
                .zip(bt.row(j))
                .map(|(&x, &y)| x as i32 * y as i32)
                .sum()
        })
    }

    #[test]
    fn blocked_matches_naive_across_shapes_and_threads() {
        let mut rng = Rng::new(0xB10C);
        for (m, k, n) in [
            (1, 1, 1),
            (4, 8, 8),
            (3, 5, 7),
            (5, 4, 9),
            (17, 33, 9),
            (64, 8, 3),
            (3, 8, 64),
            (2, 0, 3),
        ] {
            let a = rand_i8(&mut rng, m, k);
            let bt = rand_i8(&mut rng, n, k);
            let want = naive_i32(&a, &bt);
            for threads in [1usize, 4] {
                let cfg = KernelConfig {
                    threads,
                    ..KernelConfig::default()
                };
                let got = int8_gemm_blocked(&a, &bt, &cfg).unwrap();
                assert_eq!(got.data(), want.data(), "{m}x{k}x{n} threads={threads}");
            }
        }
    }

    #[test]
    fn tiny_blocking_parameters_still_exact() {
        let mut rng = Rng::new(0xB10D);
        let a = rand_i8(&mut rng, 9, 13);
        let bt = rand_i8(&mut rng, 11, 13);
        let want = naive_i32(&a, &bt);
        for kc in [1usize, 2, 12, 13, 14, 1024] {
            let cfg = KernelConfig {
                mc: MR_I8,
                nc: NR_I8,
                kc,
                threads: 2,
                ..KernelConfig::default()
            };
            let got = int8_gemm_blocked(&a, &bt, &cfg).unwrap();
            assert_eq!(got.data(), want.data(), "kc={kc}");
        }
    }

    #[test]
    fn saturated_inputs_at_the_i32_boundary_are_exact() {
        // K at the exact bound with worst-case ±127 entries: the largest
        // magnitude an i32 accumulator must hold without wrapping.
        let k = MAX_EXACT_I32_TERMS;
        let a = Mat::from_fn(1, k, |_, _| 127i8);
        let bt = Mat::from_fn(1, k, |_, _| -127i8);
        let cfg = KernelConfig {
            threads: 1,
            ..KernelConfig::default()
        };
        let c = int8_gemm_blocked(&a, &bt, &cfg).unwrap();
        assert_eq!(c.get(0, 0) as i64, -(k as i64) * 127 * 127);
    }

    #[test]
    fn k_past_the_bound_is_rejected() {
        let k = MAX_EXACT_I32_TERMS + 1;
        let a = Mat::from_fn(1, k, |_, _| 127i8);
        let bt = Mat::from_fn(1, k, |_, _| -127i8);
        let err = int8_gemm_blocked(&a, &bt, &KernelConfig::default()).unwrap_err();
        assert!(matches!(err, Error::Numerical(_)), "got {err:?}");
    }

    #[test]
    fn fused_sweep_wide_path_is_exact_past_the_i32_bound() {
        // K·splits beyond the i32 bound: diagonal d=2 sums 3·K terms of
        // -127² and would wrap i32; the i64 fallback must stay exact.
        let splits = 3usize;
        let k = MAX_EXACT_I32_TERMS / 2; // k*splits > bound, single pair fits
        let planes_a: Vec<Mat<i8>> =
            (0..splits).map(|_| Mat::from_fn(1, k, |_, _| 127i8)).collect();
        let planes_b: Vec<Mat<i8>> = (0..splits)
            .map(|_| Mat::from_fn(1, k, |_, _| -127i8))
            .collect();
        let ap = Panels::pack_planes(&planes_a, MR_I8);
        let bp = Panels::pack_planes(&planes_b, NR_I8);
        let weights = [1.0f64, 1.0, 1.0];
        let c = fused_ozaki_sweep(&ap, &bp, &weights, &KernelConfig::default()).unwrap();
        // Σ_d (d+1)·K·(−127²) = 6·K·(−16129), exact in f64 (< 2^53).
        let want = -6.0 * k as f64 * 16129.0;
        assert_eq!(c.get(0, 0), want);
    }

    #[test]
    fn sweep_many_is_bitwise_equal_to_individual_sweeps() {
        // The multi-C driver must be pure scheduling: each member's
        // matrix equals its standalone sweep bit-for-bit, for ragged
        // shapes, mixed sizes, and any thread count.
        let mut rng = Rng::new(0xBA7C);
        let mut planes = |r: usize, k: usize, s: usize| -> Vec<Mat<i8>> {
            (0..s).map(|_| rand_i8(&mut rng, r, k)).collect()
        };
        let shapes = [(7usize, 5usize, 3usize, 3usize), (16, 16, 16, 4), (1, 33, 9, 2)];
        let packed: Vec<(Panels<i8>, Panels<i8>, Vec<f64>)> = shapes
            .iter()
            .map(|&(m, k, n, s)| {
                let pa = Panels::pack_planes(&planes(m, k, s), MR_I8);
                let pb = Panels::pack_planes(&planes(n, k, s), NR_I8);
                let w: Vec<f64> = (0..s).map(|d| 0.5f64.powi(d as i32)).collect();
                (pa, pb, w)
            })
            .collect();
        for threads in [1usize, 3] {
            let cfg = KernelConfig {
                threads,
                ..KernelConfig::default()
            };
            let specs: Vec<SweepSpec<'_>> = packed
                .iter()
                .map(|(pa, pb, w)| SweepSpec {
                    ap: pa,
                    bp: pb,
                    weights: w,
                })
                .collect();
            let many = fused_ozaki_sweep_many(&specs, &cfg).unwrap();
            for (got, (pa, pb, w)) in many.iter().zip(&packed) {
                let want = fused_ozaki_sweep(pa, pb, w, &cfg).unwrap();
                assert_eq!(got.data(), want.data(), "threads={threads}");
            }
        }
        // empty batch is a no-op
        assert!(fused_ozaki_sweep_many(&[], &KernelConfig::default())
            .unwrap()
            .is_empty());
    }

    #[test]
    fn sweep_many_rejects_any_bad_member_up_front() {
        let mut rng = Rng::new(0xBA7D);
        let good_a = Panels::pack_planes(&[rand_i8(&mut rng, 4, 6)], MR_I8);
        let good_b = Panels::pack_planes(&[rand_i8(&mut rng, 8, 6)], NR_I8);
        let bad_b = Panels::pack_planes(&[rand_i8(&mut rng, 8, 7)], NR_I8); // K mismatch
        let cfg = KernelConfig::default();
        let w = [1.0f64];
        let specs = [
            SweepSpec { ap: &good_a, bp: &good_b, weights: &w },
            SweepSpec { ap: &good_a, bp: &bad_b, weights: &w },
        ];
        assert!(fused_ozaki_sweep_many(&specs, &cfg).is_err());
    }

    #[test]
    fn fused_sweep_rejects_mismatched_panels() {
        let a = Panels::pack_planes(&[Mat::<i8>::zeros(2, 3)], MR_I8);
        let b_badk = Panels::pack_planes(&[Mat::<i8>::zeros(2, 4)], NR_I8);
        let cfg = KernelConfig::default();
        assert!(fused_ozaki_sweep(&a, &b_badk, &[1.0], &cfg).is_err());
        let b_badtile = Panels::pack_planes(&[Mat::<i8>::zeros(2, 3)], MR_I8);
        assert!(fused_ozaki_sweep(&a, &b_badtile, &[1.0], &cfg).is_err());
    }

    #[test]
    fn is_wide_flips_exactly_at_the_i32_term_bound() {
        // The escape predicate is K·splits against the term budget —
        // off-by-one here silently wraps i32 accumulators.
        assert!(!is_wide(MAX_EXACT_I32_TERMS, 1));
        assert!(is_wide(MAX_EXACT_I32_TERMS + 1, 1));
        // It is the product that crosses, not K alone.
        let k = MAX_EXACT_I32_TERMS / 3;
        assert!(!is_wide(k, 3), "{}*3 <= bound", k);
        assert!(is_wide(k + 1, 3), "{}*3 > bound", k + 1);
        assert!(!is_wide(0, crate::ozaki::MAX_SPLITS));
        // Absurd K must saturate, not wrap around to "narrow".
        assert!(is_wide(usize::MAX, 2));
    }

    #[test]
    fn every_isa_matches_scalar_across_the_wide_threshold() {
        // The i32→i64 overflow escape must flip at exactly K·splits =
        // MAX_EXACT_I32_TERMS on every vector path, with results
        // bit-identical to the scalar oracle on both sides of the line.
        let splits = 2usize;
        let below = MAX_EXACT_I32_TERMS / splits;
        let above = below + 1;
        assert!(!is_wide(below, splits as u32));
        assert!(is_wide(above, splits as u32));
        let mut rng = Rng::new(0x51D3);
        for k in [below, above] {
            let pa: Vec<Mat<i8>> = (0..splits).map(|_| rand_i8(&mut rng, 5, k)).collect();
            let pb: Vec<Mat<i8>> = (0..splits).map(|_| rand_i8(&mut rng, 9, k)).collect();
            let ap = Panels::pack_planes(&pa, MR_I8);
            let bp = Panels::pack_planes(&pb, NR_I8);
            let w = [1.0f64, 0.5];
            let scalar_cfg = KernelConfig {
                simd: SimdSelect::Scalar,
                threads: 1,
                ..KernelConfig::default()
            };
            let want = fused_ozaki_sweep(&ap, &bp, &w, &scalar_cfg).unwrap();
            for isa in available_isas() {
                let cfg = KernelConfig {
                    simd: SimdSelect::Force(isa),
                    threads: 2,
                    ..KernelConfig::default()
                };
                let got = fused_ozaki_sweep(&ap, &bp, &w, &cfg).unwrap();
                assert_eq!(
                    got.data(),
                    want.data(),
                    "isa={} k={k} wide={}",
                    isa.name(),
                    is_wide(k, splits as u32)
                );
            }
        }
    }

    #[test]
    fn wide_escape_is_exact_at_saturation_on_every_isa() {
        // Worst-case ±127 planes just past the bound: an i32 path would
        // wrap; the i64 escape must hold the exact analytic value no
        // matter which ISA the narrow path is routed to.
        let splits = 2usize;
        let k = MAX_EXACT_I32_TERMS / splits + 1;
        let pa: Vec<Mat<i8>> = (0..splits)
            .map(|_| Mat::from_fn(1, k, |_, _| 127i8))
            .collect();
        let pb: Vec<Mat<i8>> = (0..splits)
            .map(|_| Mat::from_fn(1, k, |_, _| -127i8))
            .collect();
        let ap = Panels::pack_planes(&pa, MR_I8);
        let bp = Panels::pack_planes(&pb, NR_I8);
        // Anti-diagonals hold 1, 2, 1 plane pairs: Σ = 4·K·(−127²).
        let want = -4.0 * k as f64 * 16129.0;
        for isa in available_isas() {
            let cfg = KernelConfig {
                simd: SimdSelect::Force(isa),
                threads: 1,
                ..KernelConfig::default()
            };
            let c = fused_ozaki_sweep(&ap, &bp, &[1.0, 1.0], &cfg).unwrap();
            assert_eq!(c.get(0, 0), want, "isa={}", isa.name());
        }
    }

    #[test]
    fn isolated_sweep_matches_the_collecting_wrapper_when_healthy() {
        let mut rng = Rng::new(0xBA7E);
        let pa = Panels::pack_planes(&[rand_i8(&mut rng, 6, 10)], MR_I8);
        let pb = Panels::pack_planes(&[rand_i8(&mut rng, 7, 10)], NR_I8);
        let w = [1.0f64];
        let spec = || SweepSpec {
            ap: &pa,
            bp: &pb,
            weights: &w,
        };
        let specs = [spec(), spec()];
        let cfg = KernelConfig::default();
        let isolated = fused_ozaki_sweep_many_isolated(&specs, &cfg).unwrap();
        let plain = fused_ozaki_sweep_many(&specs, &cfg).unwrap();
        assert_eq!(isolated.len(), 2);
        for (got, want) in isolated.iter().zip(&plain) {
            assert_eq!(got.as_ref().unwrap().data(), want.data());
        }
        assert!(fused_ozaki_sweep_many_isolated(&[], &cfg).unwrap().is_empty());
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn injected_band_panic_fails_only_its_own_member() {
        use crate::faults::{arm, disarm_all, should_fire, FaultSite};
        let _g = crate::faults::test_guard();
        let mut rng = Rng::new(0xBA7F);
        let packed: Vec<(Panels<i8>, Panels<i8>)> = (0..3)
            .map(|_| {
                (
                    Panels::pack_planes(&[rand_i8(&mut rng, 5, 8)], MR_I8),
                    Panels::pack_planes(&[rand_i8(&mut rng, 6, 8)], NR_I8),
                )
            })
            .collect();
        let w = [1.0f64];
        let specs: Vec<SweepSpec<'_>> = packed
            .iter()
            .map(|(pa, pb)| SweepSpec {
                ap: pa,
                bp: pb,
                weights: &w,
            })
            .collect();
        // threads=1 → one band per member, run inline in member order,
        // so draw i belongs to member i.  Find a seed whose first three
        // draws mix fire and survive, replay it, and check the damage
        // lands only where the plan says.
        let seed = (0u64..64)
            .find(|&s| {
                arm(FaultSite::WorkerPanic, 0.5, s);
                let p: Vec<bool> = (0..3).map(|_| should_fire(FaultSite::WorkerPanic)).collect();
                p.iter().any(|&b| b) && !p.iter().all(|&b| b)
            })
            .expect("some seed in 0..64 mixes fire/survive at p=0.5");
        arm(FaultSite::WorkerPanic, 0.5, seed);
        let plan: Vec<bool> = (0..3).map(|_| should_fire(FaultSite::WorkerPanic)).collect();
        let cfg = KernelConfig {
            threads: 1,
            ..KernelConfig::default()
        };
        let clean: Vec<Mat<f64>> = packed
            .iter()
            .map(|(pa, pb)| fused_ozaki_sweep(pa, pb, &w, &cfg).unwrap())
            .collect();
        arm(FaultSite::WorkerPanic, 0.5, seed); // replay the same draws
        let got = fused_ozaki_sweep_many_isolated(&specs, &cfg).unwrap();
        disarm_all();
        for (i, (member, &fires)) in got.iter().zip(&plan).enumerate() {
            match member {
                Err(e) => {
                    assert!(fires, "member {i} failed off-plan");
                    assert!(
                        e.to_string().contains("fault injection"),
                        "member {i}: {e}"
                    );
                }
                Ok(c) => {
                    assert!(!fires, "member {i} survived off-plan");
                    // Survivors are bit-identical to an uninjected run.
                    assert_eq!(c.data(), clean[i].data(), "member {i}");
                }
            }
        }
        // The pool is unpoisoned: the same batch runs clean afterwards.
        let healthy = fused_ozaki_sweep_many(&specs, &cfg).unwrap();
        for (c, want) in healthy.iter().zip(&clean) {
            assert_eq!(c.data(), want.data());
        }
    }

    #[test]
    fn wide_tile_sweep_is_bit_identical_to_the_classic_tile() {
        // B packed with NR=16 vs NR=8: same fused sweep, same bits, on
        // every ISA and thread count — the register-tile variant is a
        // throughput knob only (the autotuner's selection contract).
        let mut rng = Rng::new(0x16E);
        for (m, k, n, s) in [
            (1usize, 1usize, 1usize, 2usize),
            (7, 13, 15, 3),
            (9, 33, 17, 4),
            (21, 16, 40, 6),
        ] {
            let pa: Vec<Mat<i8>> = (0..s).map(|_| rand_i8(&mut rng, m, k)).collect();
            let pb: Vec<Mat<i8>> = (0..s).map(|_| rand_i8(&mut rng, n, k)).collect();
            let ap = Panels::pack_planes(&pa, MR_I8);
            let bp8 = Panels::pack_planes(&pb, NR_I8);
            let bp16 = Panels::pack_planes(&pb, NR_I8_WIDE);
            let w: Vec<f64> = (0..s).map(|d| 0.5f64.powi(d as i32)).collect();
            let want = fused_ozaki_sweep(&ap, &bp8, &w, &KernelConfig::single_threaded()).unwrap();
            for isa in available_isas() {
                for threads in [1usize, 3] {
                    let cfg = KernelConfig {
                        simd: SimdSelect::Force(isa),
                        threads,
                        ..KernelConfig::default()
                    };
                    let got = fused_ozaki_sweep(&ap, &bp16, &w, &cfg).unwrap();
                    assert_eq!(
                        got.data(),
                        want.data(),
                        "{m}x{k}x{n} s={s} isa={} threads={threads}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn wide_tile_takes_the_i64_escape_exactly_too() {
        // NR=16 panels past the i32 bound: the wide-accumulator escape
        // must run the NR-generic scalar body and stay exact.
        let splits = 2usize;
        let k = MAX_EXACT_I32_TERMS / splits + 1;
        let pa: Vec<Mat<i8>> = (0..splits)
            .map(|_| Mat::from_fn(1, k, |_, _| 127i8))
            .collect();
        let pb: Vec<Mat<i8>> = (0..splits)
            .map(|_| Mat::from_fn(1, k, |_, _| -127i8))
            .collect();
        let ap = Panels::pack_planes(&pa, MR_I8);
        let bp = Panels::pack_planes(&pb, NR_I8_WIDE);
        let want = -4.0 * k as f64 * 16129.0;
        let c = fused_ozaki_sweep(&ap, &bp, &[1.0, 1.0], &KernelConfig::default()).unwrap();
        assert_eq!(c.get(0, 0), want);
    }

    #[test]
    fn wide_and_narrow_accumulators_agree_in_range() {
        // Same packed inputs through both Accum widths: identical sums
        // (the generic dedup must keep the escape path bit-compatible).
        let mut rng = Rng::new(0xACC);
        let a = rand_i8(&mut rng, 6, 40);
        let bt = rand_i8(&mut rng, 9, 40);
        let ap = Panels::pack_planes(std::slice::from_ref(&a), MR_I8);
        let bp = Panels::pack_planes(std::slice::from_ref(&bt), NR_I8);
        let mut n32 = [[0i32; NR_I8]; MR_I8];
        let mut n64 = [[0i64; NR_I8]; MR_I8];
        microkernel::<i32>(&mut n32, ap.panel(0, 0), bp.panel(0, 0));
        microkernel::<i64>(&mut n64, ap.panel(0, 0), bp.panel(0, 0));
        for r in 0..MR_I8 {
            for c in 0..NR_I8 {
                assert_eq!(n32[r][c] as i64, n64[r][c]);
            }
        }
    }
}
