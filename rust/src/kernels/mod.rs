//! Host kernel core: cache-blocked, panel-packed, multithreaded GEMM
//! microkernels — the "as fast as the hardware allows" CPU compute layer
//! under `linalg` and `ozaki`.
//!
//! Structure (the GotoBLAS/TVM-dp4a decomposition):
//!
//! * [`pack`] — operands are packed **once** into k-major tile panels
//!   (slice-major across Ozaki planes), so every microkernel step reads
//!   two short contiguous vectors;
//! * [`int8`] — the INT8→INT32 register-tile microkernel, the blocked
//!   single-slice GEMM ([`int8_gemm_blocked`]), and the **fused
//!   multi-slice driver** ([`fused_ozaki_sweep`]) that accumulates every
//!   retained slice pair `k+l = d` in one sweep over the packed panels
//!   with an automatic i64 escape past the exact-i32 bound
//!   ([`MAX_EXACT_I32_TERMS`]);
//! * [`fp64`] — the FP64 and fused-complex kernels on the same
//!   infrastructure ([`dgemm_blocked`], [`zgemm_blocked`]).
//!
//! Tiling and threading are governed by [`KernelConfig`]: `mc`/`nc`/`kc`
//! are the cache-block extents in matrix elements, `threads` the number
//! of row bands executed on scoped threads (`OZACCEL_THREADS`
//! overrides; default = available parallelism).  Results are bit-for-bit
//! independent of all four knobs for the integer and Ozaki paths, and of
//! `mc`/`nc`/`threads` for the FP64 path (`kc` fixes the FP64 summation
//! order, so dispatch sites share one default config).

pub mod fp64;
pub mod int8;
pub mod pack;

pub use fp64::{dgemm_blocked, zgemm_blocked, MR_C64, MR_F64, NR_C64, NR_F64};
pub use int8::{fused_ozaki_sweep, int8_gemm_blocked, MAX_EXACT_I32_TERMS, MR_I8, NR_I8};
pub use pack::{pack_cols_c64, pack_cols_f64, pack_rows_c64, pack_rows_f64, Panels};

/// Tiling + threading parameters of the blocked kernels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Row-block extent (rows of A per cache block).
    pub mc: usize,
    /// Column-block extent (columns of B per cache block).
    pub nc: usize,
    /// Contraction-block extent (elements of K per microkernel call).
    pub kc: usize,
    /// Row bands executed concurrently via `std::thread::scope`.
    pub threads: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            mc: 128,
            nc: 256,
            kc: 256,
            threads: default_threads(),
        }
    }
}

impl KernelConfig {
    /// Default tiling, single-threaded (deterministic CI baseline).
    pub fn single_threaded() -> Self {
        KernelConfig {
            threads: 1,
            ..KernelConfig::default()
        }
    }

    /// Default tiling with an explicit thread count.
    pub fn with_threads(threads: usize) -> Self {
        KernelConfig {
            threads: threads.max(1),
            ..KernelConfig::default()
        }
    }
}

/// Thread-count default: `OZACCEL_THREADS` if set to a positive
/// integer (invalid values are ignored here; `config::RunConfig`
/// rejects them loudly), otherwise the machine's available
/// parallelism.  Resolved once per process — `KernelConfig::default()`
/// sits on the per-GEMM hot path and must not re-read the environment.
pub fn default_threads() -> usize {
    static DEFAULT: once_cell::sync::Lazy<usize> = once_cell::sync::Lazy::new(|| {
        if let Ok(v) = std::env::var("OZACCEL_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    });
    *DEFAULT
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = KernelConfig::default();
        assert!(c.mc >= MR_I8 && c.nc >= NR_I8 && c.kc >= 1 && c.threads >= 1);
    }

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(KernelConfig::with_threads(0).threads, 1);
        assert_eq!(KernelConfig::with_threads(7).threads, 7);
        assert_eq!(KernelConfig::single_threaded().threads, 1);
    }
}
