//! Host kernel core: cache-blocked, panel-packed, multithreaded GEMM
//! microkernels — the "as fast as the hardware allows" CPU compute layer
//! under `linalg` and `ozaki`.
//!
//! Structure (the GotoBLAS/TVM-dp4a decomposition):
//!
//! * [`pack`] — operands are packed **once** into k-major tile panels
//!   (slice-major across Ozaki planes), so every microkernel step reads
//!   two short contiguous vectors; packing itself runs as parallel
//!   tile-block tasks on the persistent worker pool
//!   ([`crate::runtime::pool`]) when `pack_parallel` is set;
//! * [`int8`] — the INT8 register-tile microkernel, the blocked
//!   single-slice GEMM ([`int8_gemm_blocked`]), and the **fused
//!   multi-slice driver** ([`fused_ozaki_sweep`]) that accumulates every
//!   retained slice pair `k+l = d` in one sweep over the packed panels
//!   with an automatic i64 escape past the exact-i32 bound
//!   ([`MAX_EXACT_I32_TERMS`]); both walk KC-resident panel windows
//!   ([`pack::Panels::panel_window`]) so large-K GEMMs stream from
//!   cache;
//! * [`simd`] — explicit AVX2/AVX-512/NEON INT8 microkernels behind
//!   the [`Microkernel`] trait, runtime-dispatched per
//!   [`KernelConfig::simd`] with the scalar body as the
//!   always-available fallback and oracle (bit-identical by exact
//!   integer accumulation);
//! * [`fp64`] — the FP64 and fused-complex kernels on the same
//!   infrastructure ([`dgemm_blocked`], [`zgemm_blocked`]);
//! * [`panel_cache`] — a capacity-bounded, content-addressed reuse
//!   cache for packed Ozaki panels, so repeated GEMMs on the same
//!   operands (LU trailing updates, the four complex component
//!   products, SCF iterations) skip the split/pack stage entirely.
//!
//! All four band drivers share one [`run_bands`] scaffold: the output
//! is cut into whole-tile row bands and each band executes as one task
//! on the persistent pool — no per-call thread spawns.  Tiling and
//! threading are governed by [`KernelConfig`]: `mc`/`nc`/`kc` are the
//! cache-block extents, `threads` the number of row bands
//! (`OZACCEL_THREADS` overrides; default = available parallelism),
//! `pack_parallel` gates pool-parallel packing, and `panel_cache_mb`
//! bounds the packed-panel cache (0 disables it).  Results are
//! bit-for-bit independent of all knobs for the integer and Ozaki
//! paths, and of everything except `kc` for the FP64 path (`kc` fixes
//! the FP64 summation order, so dispatch sites share one default
//! config).

pub mod fp64;
pub mod int8;
pub mod pack;
pub mod panel_cache;
pub mod simd;

pub use fp64::{dgemm_blocked, zgemm_blocked, MR_C64, MR_F64, NR_C64, NR_F64};
pub use int8::{
    fused_ozaki_sweep, fused_ozaki_sweep_many, fused_ozaki_sweep_many_isolated,
    int8_gemm_blocked, is_wide, SweepSpec, MAX_EXACT_I32_TERMS, MR_I8, NR_I8, NR_I8_WIDE,
};
pub use simd::{available_isas, Isa, Microkernel, SimdSelect};
pub use pack::{
    pack_cols_c64, pack_cols_c64_mt, pack_cols_f64, pack_cols_f64_mt, pack_rows_c64,
    pack_rows_c64_mt, pack_rows_f64, pack_rows_f64_mt, Panels,
};
pub use panel_cache::{CacheStats, PanelCache, Side};

use crate::runtime::pool::{self, SendPtr};

/// Tiling + threading parameters of the blocked kernels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Row-block extent (rows of A per cache block).
    pub mc: usize,
    /// Column-block extent (columns of B per cache block).
    pub nc: usize,
    /// Contraction-block extent (elements of K per microkernel call).
    pub kc: usize,
    /// Row bands executed concurrently on the persistent worker pool.
    pub threads: usize,
    /// Run the split/pack stage as parallel tile-block tasks on the
    /// same pool (`run.pack_parallel`; results are identical either
    /// way — rows are packed independently).
    pub pack_parallel: bool,
    /// Packed-panel reuse cache budget in MiB (`run.panel_cache_mb`);
    /// 0 disables the cache.
    pub panel_cache_mb: usize,
    /// INT8 microkernel ISA routing (`run.simd` / `OZACCEL_SIMD`):
    /// [`SimdSelect::Auto`] picks the best runtime-detected ISA,
    /// [`SimdSelect::Scalar`] pins the autovectorized oracle body.
    /// Results are bit-identical either way (exact integer
    /// accumulation); only speed changes.
    pub simd: SimdSelect,
    /// INT8 B-panel register-tile width: [`NR_I8`] (the classic 8-wide
    /// tile) or [`NR_I8_WIDE`] (the AVX-512 native-width 16-wide tile).
    /// Like every other knob on the Ozaki path this is bit-invisible
    /// (exact integer accumulation) — only speed changes.  The FP64
    /// kernels ignore it.
    pub nr: usize,
    /// Tuning-cache consultation mode (`run.tune` / `OZACCEL_TUNE`):
    /// whether [`crate::coordinator::KernelSelector`] may override the
    /// blocking constants per call shape from the persistent autotuner
    /// cache (see [`crate::tune`]).
    pub tune: crate::tune::TuneMode,
    /// Explicit tuning-cache path (`tune.file` / `OZACCEL_TUNE_FILE`);
    /// `None` resolves to `$OZACCEL_TUNE_FILE` then
    /// `~/.cache/ozaccel/tuning.toml`.
    pub tune_file: Option<std::path::PathBuf>,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            mc: 128,
            nc: 256,
            kc: 256,
            threads: default_threads(),
            pack_parallel: true,
            panel_cache_mb: panel_cache::DEFAULT_CAPACITY_MB,
            simd: SimdSelect::Auto,
            nr: NR_I8,
            tune: crate::tune::TuneMode::Off,
            tune_file: None,
        }
    }
}

impl KernelConfig {
    /// Default tiling, single-threaded (deterministic CI baseline).
    pub fn single_threaded() -> Self {
        KernelConfig {
            threads: 1,
            ..KernelConfig::default()
        }
    }

    /// Default tiling with an explicit thread count.
    pub fn with_threads(threads: usize) -> Self {
        KernelConfig {
            threads: threads.max(1),
            ..KernelConfig::default()
        }
    }

    /// Clamp the blocking constants to register-tile compatibility.
    ///
    /// **Invariant:** the blocked drivers assume `mc` is a positive
    /// multiple of the A-side register tile ([`MR_I8`]), `nc` a
    /// positive multiple of the B-side tile (`nr`), and `kc >= 1`; a
    /// non-multiple silently degrades every cache block to the
    /// ragged-edge path.  `nr` itself must be one of the two packed
    /// tile widths ([`NR_I8`] / [`NR_I8_WIDE`]).  Dispatch resolves
    /// every config through this method
    /// (`KernelSelector::effective_config`), so hand-built or tuned
    /// configs are normalized before they reach a kernel.  Clamping
    /// rounds **down** (never above a user-requested cache footprint)
    /// and is a no-op on the defaults.  Bit-identity is unaffected:
    /// these knobs are invisible to Ozaki/INT8 results, and the FP64
    /// path's `kc` is only floored at the same `max(1)` the kernels
    /// already apply.
    #[must_use]
    pub fn clamped(&self) -> Self {
        let nr = if self.nr == NR_I8_WIDE { NR_I8_WIDE } else { NR_I8 };
        KernelConfig {
            mc: (self.mc / MR_I8).max(1) * MR_I8,
            nc: (self.nc / nr).max(1) * nr,
            kc: self.kc.max(1),
            threads: self.threads.max(1),
            nr,
            ..self.clone()
        }
    }

    /// Threads the pack stage may use (1 when parallel pack is off).
    #[inline]
    pub fn pack_threads(&self) -> usize {
        if self.pack_parallel {
            self.threads.max(1)
        } else {
            1
        }
    }
}

/// Shared row-band scaffold of the four blocked drivers.
///
/// `c` is the `rows x n` row-major output of a kernel whose A-side was
/// packed with `tile` rows per panel (`m_tiles` tiles).  The output is
/// cut into contiguous whole-tile row bands — `ceil(m_tiles / threads)`
/// tiles each, the last possibly ragged — and `band(slice, tile0)` runs
/// for each as one task on the persistent worker pool.
///
/// The partition depends only on `threads`, and every band writes a
/// pure function of the packed inputs into its own disjoint slice, so
/// results are bit-for-bit independent of the pool's actual
/// parallelism — the same contract the scoped-thread code this
/// replaces provided.
pub fn run_bands<T, F>(c: &mut [T], n: usize, tile: usize, m_tiles: usize, threads: usize, band: F)
where
    T: Send,
    F: Fn(&mut [T], usize) + Sync,
{
    if c.is_empty() || n == 0 || m_tiles == 0 {
        return;
    }
    if threads.max(1).min(m_tiles) <= 1 {
        // Single band: run inline, no partition or pool traffic.
        band(c, 0);
        return;
    }
    let ranges = band_ranges(c.len(), n, tile, m_tiles, threads);
    debug_assert_eq!(ranges.len(), band_count(m_tiles, threads), "bands_for must match");
    let base = SendPtr(c.as_mut_ptr());
    pool::run(ranges.len(), threads, |bi| {
        let (start, end, tile0) = ranges[bi];
        // Safety: bands are disjoint in-bounds subslices of `c`.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.get().add(start), end - start) };
        band(slice, tile0);
    });
}

/// The exact band cuts [`run_bands`] executes for an output of `len`
/// elements (`n` columns, `tile` rows per A-side tile, `m_tiles`
/// tiles) at a requested `threads`: one `(start, end, tile0)` element
/// range per band, in band order.
///
/// This is the **single home** of the partition arithmetic, shared
/// with the multi-C batch driver ([`fused_ozaki_sweep_many`]) so the
/// engine's bit-identity contract ("batched band cuts equal per-call
/// band cuts") holds by construction, and consistent with
/// [`band_count`] (pinned by a debug assertion in `run_bands`).
pub fn band_ranges(
    len: usize,
    n: usize,
    tile: usize,
    m_tiles: usize,
    threads: usize,
) -> Vec<(usize, usize, usize)> {
    if len == 0 || n == 0 || m_tiles == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(m_tiles);
    if threads <= 1 {
        return vec![(0, len, 0)];
    }
    let tiles_per_band = m_tiles.div_ceil(threads);
    let chunk = tiles_per_band * tile * n;
    (0..len.div_ceil(chunk))
        .map(|bi| (bi * chunk, ((bi + 1) * chunk).min(len), bi * tiles_per_band))
        .collect()
}

/// Number of row bands [`run_bands`] cuts for `m_tiles` A-side tiles at
/// a requested `threads` — the single home of the partition arithmetic,
/// shared with the PEAK report's `KernelSelector::bands_for` (and
/// pinned against `run_bands` by a debug assertion there).
pub fn band_count(m_tiles: usize, threads: usize) -> usize {
    let m_tiles = m_tiles.max(1);
    let threads = threads.max(1).min(m_tiles);
    let tiles_per_band = m_tiles.div_ceil(threads);
    m_tiles.div_ceil(tiles_per_band)
}

/// Thread-count default: `OZACCEL_THREADS` if set to a positive
/// integer (a malformed or zero value aborts loudly — see
/// [`crate::util::env`]), otherwise the machine's available
/// parallelism.  Resolved once per process — `KernelConfig::default()`
/// sits on the per-GEMM hot path and must not re-read the environment.
pub fn default_threads() -> usize {
    static DEFAULT: once_cell::sync::Lazy<usize> = once_cell::sync::Lazy::new(|| {
        crate::util::env::parse_env_checked::<usize>(
            "OZACCEL_THREADS",
            "an integer >= 1",
            |&n| n >= 1,
        )
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
    });
    *DEFAULT
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = KernelConfig::default();
        assert!(c.mc >= MR_I8 && c.nc >= NR_I8 && c.kc >= 1 && c.threads >= 1);
        assert!(c.pack_parallel);
        assert_eq!(c.panel_cache_mb, panel_cache::DEFAULT_CAPACITY_MB);
        assert_eq!(c.simd, SimdSelect::Auto);
        assert!(c.simd.resolve().available());
        assert_eq!(c.nr, NR_I8);
        assert_eq!(c.tune, crate::tune::TuneMode::Off);
        assert!(c.tune_file.is_none());
        assert_eq!(c.clamped(), c, "defaults are already tile-aligned");
    }

    #[test]
    fn clamped_rounds_down_to_tile_multiples() {
        let c = KernelConfig {
            mc: 130,
            nc: 250,
            kc: 0,
            threads: 0,
            nr: 16,
            ..KernelConfig::default()
        };
        let k = c.clamped();
        assert_eq!((k.mc, k.nc, k.kc, k.threads, k.nr), (128, 240, 1, 1, NR_I8_WIDE));
        // Sub-tile requests floor to one whole tile, bogus nr to NR_I8.
        let tiny = KernelConfig { mc: 1, nc: 3, nr: 5, ..KernelConfig::default() }.clamped();
        assert_eq!((tiny.mc, tiny.nc, tiny.nr), (MR_I8, NR_I8, NR_I8));
    }

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(KernelConfig::with_threads(0).threads, 1);
        assert_eq!(KernelConfig::with_threads(7).threads, 7);
        assert_eq!(KernelConfig::single_threaded().threads, 1);
    }

    #[test]
    fn pack_threads_respects_the_gate() {
        let mut c = KernelConfig::with_threads(6);
        assert_eq!(c.pack_threads(), 6);
        c.pack_parallel = false;
        assert_eq!(c.pack_threads(), 1);
    }

    #[test]
    fn run_bands_partitions_like_chunks_mut() {
        // 10 tiles of 4 rows, 3 columns, 4 bands: bands of 3/3/3/1 tiles.
        let (tile, m_tiles, n) = (4usize, 10usize, 3usize);
        let rows = 37; // ragged final tile
        let mut c = vec![0usize; rows * n];
        run_bands(&mut c, n, tile, m_tiles, 4, |band, tile0| {
            band.fill(tile0 + 1);
        });
        // rows 0..12 -> tile0 0, 12..24 -> 3, 24..36 -> 6, 36..37 -> 9
        assert!(c[..12 * n].iter().all(|&v| v == 1));
        assert!(c[12 * n..24 * n].iter().all(|&v| v == 4));
        assert!(c[24 * n..36 * n].iter().all(|&v| v == 7));
        assert!(c[36 * n..].iter().all(|&v| v == 10));
    }

    #[test]
    fn band_ranges_cover_disjointly_and_match_band_count() {
        for (len, n, tile, m_tiles, threads) in [
            (37 * 3, 3usize, 4usize, 10usize, 4usize),
            (12, 3, 4, 1, 8),
            (100 * 5, 5, 4, 25, 6),
            (7 * 2, 2, 4, 2, 2),
        ] {
            let ranges = band_ranges(len, n, tile, m_tiles, threads);
            assert_eq!(ranges.len(), band_count(m_tiles, threads), "{m_tiles}/{threads}");
            // contiguous, disjoint, covering [0, len)
            let mut pos = 0;
            for (i, &(start, end, tile0)) in ranges.iter().enumerate() {
                assert_eq!(start, pos);
                assert!(end > start);
                assert_eq!(tile0, i * m_tiles.div_ceil(threads.max(1).min(m_tiles)));
                pos = end;
            }
            assert_eq!(pos, len);
        }
        assert!(band_ranges(0, 3, 4, 10, 4).is_empty());
    }

    #[test]
    fn run_bands_single_thread_gets_everything() {
        let mut c = vec![0u8; 12];
        run_bands(&mut c, 3, 4, 1, 8, |band, tile0| {
            assert_eq!(tile0, 0);
            assert_eq!(band.len(), 12);
            band.fill(7);
        });
        assert!(c.iter().all(|&v| v == 7));
    }
}
