//! Packed-panel reuse cache: content-addressed, capacity-bounded LRU
//! storage for the Ozaki split/pack products.
//!
//! The split+pack stage is the dominant per-call cost of small emulated
//! GEMMs, and real workloads repeat operands constantly: the four
//! component products of one complex GEMM share their A/B planes, LU
//! trailing updates re-multiply the same L21 panel, and SCF iterations
//! re-factor nearly identical matrices call after call.  This cache
//! lets `ozaki_dgemm` / `ozaki_zgemm` reuse the packed slice panels
//! (and the per-row scaling exponents) across such calls instead of
//! re-splitting — the packed-A reuse trick of the EmuGEMM / NVIDIA
//! Ozaki-extension line of work, applied on the host.
//!
//! Keys are **content fingerprints** (a SplitMix64-mixed digest of the
//! raw f64 bits — see [`fingerprint`] for why full per-word avalanche
//! is load-bearing) plus shape, split count, pack tile width, and
//! operand side — never bare pointers — so
//! aliased copies of the same matrix hit, and in-place mutation misses
//! by construction (the stale entry simply ages out of the LRU).  A hit
//! therefore always returns exactly the panels a fresh pack would
//! produce, and cached results stay bit-for-bit identical to uncached
//! ones.  The fingerprint costs one pass over the operand, against the
//! `splits` scale/truncate passes (plus, for B, a transpose) it saves.
//!
//! Capacity is bounded in bytes (`run.panel_cache_mb`, default
//! [`DEFAULT_CAPACITY_MB`]); eviction is LRU.  Statistics (hits,
//! misses, evictions, cumulative pack seconds) feed the PEAK per-site
//! report through the dispatcher.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::pack::Panels;

/// Default cache budget in MiB.
pub const DEFAULT_CAPACITY_MB: usize = 64;

/// Which operand layout a cached entry holds: A-side panels are packed
/// with the `MR` tile from the operand's rows; B-side panels with the
/// `NR` tile from the operand's *columns* (the transpose happens at
/// pack time, so a B-side hit skips the transpose too).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Side {
    /// Row-major A operand, packed with the `MR` tile.
    A,
    /// Column-packed B operand (transposed at pack time), `NR` tile.
    B,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Key {
    side: Side,
    rows: usize,
    cols: usize,
    splits: u32,
    /// Register-tile width the panels were packed with (`MR` for A,
    /// `NR` for B).  Part of the key because the same operand packed
    /// for the 8-wide and 16-wide B tiles yields different panel
    /// layouts — the tuner switches `nr` per call shape, and a tile
    /// mismatch must miss, never alias.
    tile: usize,
    fp: u64,
}

struct Entry {
    panels: Arc<Panels<i8>>,
    exps: Arc<Vec<i32>>,
    bytes: usize,
    last_used: u64,
}

/// Cache counters (cumulative since process start for the global
/// instance; the dispatcher diffs snapshots to attribute per-call
/// pack time and cache traffic to call sites).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to pack fresh panels.
    pub misses: u64,
    /// Entries dropped to stay within the capacity bound.
    pub evictions: u64,
    /// Seconds spent packing (cache misses and uncached packs).
    pub pack_s: f64,
}

/// A capacity-bounded LRU cache of packed Ozaki panels.
pub struct PanelCache {
    map: HashMap<Key, Entry>,
    capacity: usize,
    resident: usize,
    tick: u64,
    stats: CacheStats,
}

impl PanelCache {
    /// Empty cache with the given byte capacity (0 caches nothing).
    pub fn new(capacity_bytes: usize) -> Self {
        PanelCache {
            map: HashMap::new(),
            capacity: capacity_bytes,
            resident: 0,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Current capacity bound in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity
    }

    /// Bytes of packed panels currently resident.
    pub fn resident_bytes(&self) -> usize {
        self.resident
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Cumulative counters (hits/misses/evictions/pack seconds).
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Account pack time performed outside the cache (the uncached
    /// path), so per-site pack attribution stays complete.
    pub fn note_pack(&mut self, seconds: f64) {
        self.stats.pack_s += seconds;
    }

    /// Adjust the capacity bound, evicting LRU entries if it shrank.
    pub fn set_capacity(&mut self, bytes: usize) {
        self.capacity = bytes;
        while self.resident > self.capacity && self.evict_lru(None) {}
    }

    /// Grow the capacity bound to at least `bytes` — the per-call path
    /// into the shared global cache.  Growth-only on purpose: a caller
    /// configured with a small `panel_cache_mb` must not evict a
    /// concurrent large-budget caller's working set on every call
    /// (explicit shrinking stays available via [`set_capacity`]).
    ///
    /// [`set_capacity`]: PanelCache::set_capacity
    pub fn ensure_capacity(&mut self, bytes: usize) {
        if bytes > self.capacity {
            self.capacity = bytes;
        }
    }

    /// Drop every cached entry (tests / explicit invalidation).
    pub fn clear(&mut self) {
        self.map.clear();
        self.resident = 0;
    }

    /// Look up the packed panels for (`side`, shape, `splits`, pack
    /// `tile` width, content fingerprint `fp`), counting the hit or
    /// miss.  The caller packs on a miss **without holding the cache
    /// lock** and hands the product to [`PanelCache::insert`].
    pub fn lookup(
        &mut self,
        side: Side,
        rows: usize,
        cols: usize,
        splits: u32,
        tile: usize,
        fp: u64,
    ) -> Option<(Arc<Panels<i8>>, Arc<Vec<i32>>)> {
        self.tick += 1;
        let key = Key {
            side,
            rows,
            cols,
            splits,
            tile,
            fp,
        };
        match self.map.get_mut(&key) {
            Some(e) => {
                e.last_used = self.tick;
                self.stats.hits += 1;
                Some((e.panels.clone(), e.exps.clone()))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly packed product (accounting `pack_seconds` spent
    /// outside the lock) and return the shared handles.  If another
    /// thread raced the same key in first, its identical entry wins and
    /// is returned instead.  Entries larger than the capacity bound are
    /// returned uncached.
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        side: Side,
        rows: usize,
        cols: usize,
        splits: u32,
        tile: usize,
        fp: u64,
        panels: Panels<i8>,
        exps: Vec<i32>,
        pack_seconds: f64,
    ) -> (Arc<Panels<i8>>, Arc<Vec<i32>>) {
        self.tick += 1;
        self.stats.pack_s += pack_seconds;
        let key = Key {
            side,
            rows,
            cols,
            splits,
            tile,
            fp,
        };
        if let Some(e) = self.map.get_mut(&key) {
            // a concurrent pack of the same contents landed first;
            // the entries are bit-identical, keep the resident one
            e.last_used = self.tick;
            return (e.panels.clone(), e.exps.clone());
        }
        let bytes = panels.bytes() + exps.len() * std::mem::size_of::<i32>();
        let panels = Arc::new(panels);
        let exps = Arc::new(exps);
        if bytes <= self.capacity {
            self.resident += bytes;
            self.map.insert(
                key,
                Entry {
                    panels: panels.clone(),
                    exps: exps.clone(),
                    bytes,
                    last_used: self.tick,
                },
            );
            while self.resident > self.capacity && self.evict_lru(Some(self.tick)) {}
        }
        (panels, exps)
    }

    /// Convenience for tests and single-threaded callers: [`lookup`]
    /// then pack + [`insert`] on a miss (the pack runs under the
    /// caller's borrow of the cache, i.e. with the lock held when the
    /// cache is shared — the `ozaki` prepare stage uses the split API
    /// instead to keep the global lock out of the pack).
    ///
    /// [`lookup`]: PanelCache::lookup
    /// [`insert`]: PanelCache::insert
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_pack(
        &mut self,
        side: Side,
        rows: usize,
        cols: usize,
        splits: u32,
        tile: usize,
        fp: u64,
        pack: impl FnOnce() -> (Panels<i8>, Vec<i32>),
    ) -> (Arc<Panels<i8>>, Arc<Vec<i32>>) {
        if let Some(hit) = self.lookup(side, rows, cols, splits, tile, fp) {
            return hit;
        }
        let t0 = Instant::now();
        let (panels, exps) = pack();
        let dt = t0.elapsed().as_secs_f64();
        self.insert(side, rows, cols, splits, tile, fp, panels, exps, dt)
    }

    /// Evict the least-recently-used entry, skipping (when `protect` is
    /// set) entries touched at that tick.  Returns whether an entry was
    /// evicted.
    fn evict_lru(&mut self, protect: Option<u64>) -> bool {
        let victim = self
            .map
            .iter()
            .filter(|(_, e)| match protect {
                Some(t) => e.last_used < t,
                None => true,
            })
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k);
        match victim {
            Some(k) => {
                let e = self.map.remove(&k).unwrap();
                self.resident -= e.bytes;
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }
}

/// Content digest over the raw f64 bits — the identity of a cache key.
///
/// Each word passes through the SplitMix64 finalizer
/// ([`crate::util::rng::mix64`], the shared mixer whose stability
/// contract lives with the generator) before folding into the running
/// state.  The xor-shifts matter: a plain word-wise FNV (`h ^= w; h *=
/// prime`) is closed modulo `2^t`, so matrices whose entries all share
/// `t` trailing-zero bits (every small-integer-valued f64 has ~52)
/// would get value-independent low digest bits and collide after only a
/// few thousand distinct operands.  With full avalanche per word, a
/// collision needs two same-shaped matrices agreeing on an honest
/// 64-bit digest — negligible next to the cost model this serves.
pub fn fingerprint(data: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        h = crate::util::rng::mix64(h ^ v.to_bits());
    }
    h
}

/// The process-wide cache instance the `ozaki` prepare stage uses.
pub fn global() -> &'static Mutex<PanelCache> {
    static GLOBAL: once_cell::sync::Lazy<Mutex<PanelCache>> =
        once_cell::sync::Lazy::new(|| Mutex::new(PanelCache::new(DEFAULT_CAPACITY_MB << 20)));
    &GLOBAL
}

/// Snapshot of the global cache's counters.
pub fn global_stats() -> CacheStats {
    global().lock().unwrap().stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::MR_I8;
    use crate::linalg::Mat;
    use crate::ozaki::{row_scale_exponents, split_scaled_into_panels};

    fn pack_a(a: &Mat<f64>, splits: u32) -> (Panels<i8>, Vec<i32>) {
        let ea = row_scale_exponents(a);
        let pa = split_scaled_into_panels(a, &ea, splits, MR_I8);
        (pa, ea)
    }

    #[test]
    fn second_lookup_hits_and_shares_the_allocation() {
        let mut cache = PanelCache::new(1 << 20);
        let a = Mat::from_fn(8, 8, |i, j| (i * 8 + j) as f64 * 0.125 - 3.0);
        let fp = fingerprint(a.data());
        let (p1, e1) = cache.get_or_pack(Side::A, 8, 8, 4, MR_I8, fp, || pack_a(&a, 4));
        let (p2, e2) =
            cache.get_or_pack(Side::A, 8, 8, 4, MR_I8, fp, || panic!("must not repack on a hit"));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert!(Arc::ptr_eq(&e1, &e2));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(s.pack_s >= 0.0);
    }

    #[test]
    fn aliased_copy_hits_by_content() {
        let mut cache = PanelCache::new(1 << 20);
        let a = Mat::from_fn(6, 5, |i, j| (i as f64 - j as f64) * 0.5);
        let alias = a.clone(); // different allocation, same content
        let (p1, _) =
            cache.get_or_pack(Side::A, 6, 5, 3, MR_I8, fingerprint(a.data()), || pack_a(&a, 3));
        let (p2, _) = cache.get_or_pack(Side::A, 6, 5, 3, MR_I8, fingerprint(alias.data()), || {
            panic!("aliased content must hit")
        });
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn mutation_invalidates_by_fingerprint() {
        let mut cache = PanelCache::new(1 << 20);
        let mut a = Mat::from_fn(4, 4, |i, j| (i + j) as f64 + 0.25);
        let fp1 = fingerprint(a.data());
        let (p1, _) = cache.get_or_pack(Side::A, 4, 4, 3, MR_I8, fp1, || pack_a(&a, 3));
        a.set(2, 2, -17.5); // in-place mutation, same allocation
        let fp2 = fingerprint(a.data());
        assert_ne!(fp1, fp2);
        let (p2, _) = cache.get_or_pack(Side::A, 4, 4, 3, MR_I8, fp2, || pack_a(&a, 3));
        assert!(!Arc::ptr_eq(&p1, &p2), "mutated operand must repack");
        assert_eq!(cache.stats().misses, 2);
        // fresh pack of the mutated matrix matches the cached copy
        let fresh = pack_a(&a, 3).0;
        for s in 0..3 {
            for i in 0..4 {
                for p in 0..4 {
                    assert_eq!(p2.get(s, i, p), fresh.get(s, i, p));
                }
            }
        }
    }

    #[test]
    fn splits_and_side_are_part_of_the_key() {
        let mut cache = PanelCache::new(1 << 20);
        let a = Mat::from_fn(5, 5, |i, j| (i * j) as f64 * 0.1 + 0.01);
        let fp = fingerprint(a.data());
        cache.get_or_pack(Side::A, 5, 5, 3, MR_I8, fp, || pack_a(&a, 3));
        cache.get_or_pack(Side::A, 5, 5, 4, MR_I8, fp, || pack_a(&a, 4));
        cache.get_or_pack(Side::B, 5, 5, 3, MR_I8, fp, || pack_a(&a, 3));
        assert_eq!(cache.stats().misses, 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn pack_tile_is_part_of_the_key() {
        use crate::kernels::{NR_I8, NR_I8_WIDE};
        let mut cache = PanelCache::new(1 << 20);
        let b = Mat::from_fn(8, 16, |i, j| (i as f64 + 1.0) * 0.25 - j as f64 * 0.125);
        let fp = fingerprint(b.data());
        let pack_b = |tile: usize| {
            let eb = row_scale_exponents(&b.transposed());
            let pb = split_scaled_into_panels(&b.transposed(), &eb, 3, tile);
            (pb, eb)
        };
        let (p8, _) = cache.get_or_pack(Side::B, 8, 16, 3, NR_I8, fp, || pack_b(NR_I8));
        let (p16, _) = cache.get_or_pack(Side::B, 8, 16, 3, NR_I8_WIDE, fp, || {
            pack_b(NR_I8_WIDE)
        });
        assert!(!Arc::ptr_eq(&p8, &p16), "tile widths must not alias");
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(p8.tile(), NR_I8);
        assert_eq!(p16.tile(), NR_I8_WIDE);
    }

    #[test]
    fn capacity_bound_is_enforced_lru() {
        let mut cache = PanelCache::new(0);
        let a = Mat::from_fn(4, 4, |_, _| 0.5);
        // capacity 0: computed but never stored
        cache.get_or_pack(Side::A, 4, 4, 2, MR_I8, fingerprint(a.data()), || pack_a(&a, 2));
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);

        // small but nonzero: old entries age out, bound holds
        let one_entry = {
            let (p, e) = pack_a(&a, 2);
            p.bytes() + e.len() * 4
        };
        let mut cache = PanelCache::new(one_entry);
        for v in 0..5 {
            let m = Mat::from_fn(4, 4, |_, _| v as f64 + 0.5);
            cache.get_or_pack(Side::A, 4, 4, 2, MR_I8, fingerprint(m.data()), || pack_a(&m, 2));
            assert!(cache.resident_bytes() <= cache.capacity_bytes());
        }
        assert_eq!(cache.stats().evictions, 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn shrinking_capacity_evicts() {
        let a = Mat::from_fn(4, 4, |_, _| 1.25);
        let mut cache = PanelCache::new(1 << 20);
        cache.get_or_pack(Side::A, 4, 4, 2, MR_I8, fingerprint(a.data()), || pack_a(&a, 2));
        assert_eq!(cache.len(), 1);
        cache.set_capacity(0);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
        // ensure_capacity grows but never shrinks (per-call path into
        // the shared global cache)
        cache.ensure_capacity(1 << 10);
        assert_eq!(cache.capacity_bytes(), 1 << 10);
        cache.ensure_capacity(1 << 4);
        assert_eq!(cache.capacity_bytes(), 1 << 10);
    }

    #[test]
    fn fingerprint_is_content_sensitive() {
        let a = vec![1.0f64, 2.0, 3.0];
        let b = vec![1.0f64, 2.0, 3.0 + 1e-15];
        let c = vec![2.0f64, 1.0, 3.0];
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&c), "order matters");
    }

    #[test]
    fn fingerprint_low_bits_avalanche_on_integer_values() {
        // The degenerate class for a word-wise FNV: small-integer f64s
        // carry ~52 trailing-zero bits, which a multiply-only hash keeps
        // value-independent in the low digest bits.  The SplitMix64 mix
        // must spread them (collision here would silently serve wrong
        // panels to integer-valued workloads).
        let x = fingerprint(&[1.0, 2.0]);
        let y = fingerprint(&[3.0, 4.0]);
        assert_ne!(x & 0xFFFF, y & 0xFFFF, "low 16 bits must differ");
        // and exhaustively over a small grid: all digests distinct
        let mut seen = std::collections::HashSet::new();
        for a in 0..32 {
            for b in 0..32 {
                assert!(seen.insert(fingerprint(&[a as f64, b as f64])));
            }
        }
    }
}
