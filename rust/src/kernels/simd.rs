//! Explicit-SIMD INT8 microkernels with runtime ISA dispatch.
//!
//! The INT8 register tile used to rely on LLVM autovectorizing the
//! scalar `MR_I8 x NR_I8` dp4a-style body in [`super::int8`]; this
//! module replaces that hope with hand-written vector kernels behind a
//! small [`Microkernel`] trait:
//!
//! * **AVX2** (x86-64, runtime-detected): sign-extend two consecutive
//!   `p` steps of the packed B panel to `i16` pairs, then
//!   `vpmaddwd` (`_mm256_madd_epi16`) against a broadcast A pair —
//!   two multiply-adds per lane per instruction, no saturation
//!   anywhere (`127·127·2 < 2¹⁵·2` fits the instruction's exact `i32`
//!   output), accumulated with exact `i32` adds;
//! * **AVX-512 VNNI** (x86-64, behind the `avx512` cargo feature and
//!   runtime detection): the same pair layout through
//!   `_mm256_dpwssd_epi32`, fusing the multiply-add-accumulate into
//!   one instruction;
//! * **NEON** (aarch64): `vmull_s8` widening multiplies with `i32`
//!   widening accumulation;
//! * **scalar** — the original autovectorized body, always available,
//!   and the oracle every vector kernel is pinned against.
//!
//! Selection is a *dispatch decision*, not a compile-time fork:
//! [`SimdSelect`] (the `run.simd` / `OZACCEL_SIMD` knob, threaded
//! through [`super::KernelConfig`]) resolves to an [`Isa`] via
//! [`detect`], which probes `is_x86_feature_detected!` once per
//! process.  The resolved ISA is surfaced per call site in the PEAK
//! report.
//!
//! **Exactness.**  Every kernel accumulates `i8·i8` products in `i32`
//! integer arithmetic, which is associative and commutative as long as
//! no intermediate sum overflows — and the Ozaki drivers only enter the
//! `i32` path under the worst-case bound
//! [`super::MAX_EXACT_I32_TERMS`], where *no* ordering of the partial
//! sums can wrap.  Bit-for-bit equality across scalar/AVX2/AVX-512/NEON
//! (and any tiling or thread count) is therefore provable, not
//! aspirational; `tests/kernels_equivalence.rs` pins it anyway.  The
//! `i64` wide-accumulator escape past the bound always runs the scalar
//! body — it is exact by the same argument, and too rare to vectorize.

use super::int8::{microkernel, microkernel_nr, MR_I8, NR_I8, NR_I8_WIDE};

// The vector bodies below hard-code the 4-row x 8-column register tile
// (one 256-bit lane row per accumulator row, 8-byte B loads) and its
// 4 x 16 wide variant (two 256-bit halves per row on AVX2, one 512-bit
// lane row on AVX-512).  Retuning the tiles must be a compile error
// here, not out-of-bounds UB in the unsafe blocks.
const _: () = assert!(MR_I8 == 4 && NR_I8 == 8 && NR_I8_WIDE == 16);

/// One INT8→`i32` register-tile microkernel implementation.
///
/// `run` computes `acc[r][c] += Σ_p a_panel[p·MR+r] · b_panel[p·NR+c]`
/// over the k-major packed panels (`a_panel.len() = k·MR_I8`,
/// `b_panel.len() = k·NR_I8`) — the contract of the scalar body in
/// [`super::int8`], which every implementation must match bit-for-bit
/// (exact integer arithmetic makes any summation order equivalent).
///
/// `run_wide` is the same contract over the `MR_I8 x NR_I8_WIDE`
/// register tile (B panels packed with tile width 16 — the AVX-512
/// native-width variant the shape autotuner can select via
/// `KernelConfig::nr`).  The default body is the scalar oracle, so
/// every ISA is always wide-capable; AVX2 and AVX-512 override it with
/// vector bodies.
pub trait Microkernel: Send + Sync {
    /// ISA label shown in the PEAK report (`scalar`, `avx2`, ...).
    fn name(&self) -> &'static str;
    /// Accumulate one packed `MR_I8 x NR_I8` tile over the given panels.
    fn run(&self, acc: &mut [[i32; NR_I8]; MR_I8], a_panel: &[i8], b_panel: &[i8]);
    /// Accumulate one packed `MR_I8 x NR_I8_WIDE` (NR=16) tile.
    fn run_wide(&self, acc: &mut [[i32; NR_I8_WIDE]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        microkernel_nr::<i32, NR_I8_WIDE>(acc, a_panel, b_panel);
    }
}

/// The instruction set a resolved microkernel targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable scalar/autovectorized body — always available; the
    /// oracle the vector kernels are verified against.
    Scalar,
    /// AVX2 `vpmaddwd` kernel (x86-64).
    Avx2,
    /// AVX-512 VNNI `vpdpwssd` kernel (x86-64; compiled only with the
    /// `avx512` cargo feature).
    Avx512,
    /// NEON widening-multiply kernel (aarch64).
    Neon,
}

impl Isa {
    /// Stable lower-case label (`scalar` | `avx2` | `avx512` | `neon`).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Parse an ISA label (the `run.simd` / `OZACCEL_SIMD` values other
    /// than `scalar`/`auto`).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512vnni" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Whether this ISA can run on the current machine and build
    /// (compile-time gates and the runtime CPUID probe both count).
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => false,
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => {
                // BW is required by the NR=16 wide tile's i16 zip
                // (`vpermt2w`); every VNNI-capable CPU also has BW+VL.
                std::is_x86_feature_detected!("avx512vl")
                    && std::is_x86_feature_detected!("avx512vnni")
                    && std::is_x86_feature_detected!("avx512bw")
            }
            #[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
            Isa::Avx512 => false,
            Isa::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// The microkernel implementing this ISA.  Defensively returns the
    /// scalar body when the ISA is unavailable (callers resolve through
    /// [`SimdSelect::resolve`], which warns on that fallback).
    pub fn microkernel(self) -> &'static dyn Microkernel {
        if !self.available() {
            return &SCALAR;
        }
        match self {
            Isa::Scalar => &SCALAR,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => &AVX2,
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => &AVX512,
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => &NEON,
            #[allow(unreachable_patterns)]
            _ => &SCALAR,
        }
    }
}

/// Best ISA the current machine supports, probed once per process
/// (CPUID via `is_x86_feature_detected!`; the result is cached because
/// kernel selection sits on the per-GEMM hot path).
pub fn detect() -> Isa {
    static BEST: once_cell::sync::Lazy<Isa> = once_cell::sync::Lazy::new(|| {
        for isa in [Isa::Avx512, Isa::Avx2, Isa::Neon] {
            if isa.available() {
                return isa;
            }
        }
        Isa::Scalar
    });
    *BEST
}

/// Every ISA runnable on this machine and build, scalar first — the
/// iteration set of the cross-ISA equivalence tests and benches.
pub fn available_isas() -> Vec<Isa> {
    [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon]
        .into_iter()
        .filter(|isa| isa.available())
        .collect()
}

/// The SIMD routing policy carried by [`super::KernelConfig`]
/// (`run.simd` / `OZACCEL_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdSelect {
    /// Always the scalar/autovectorized body (the PR-1/PR-2 kernel —
    /// what `OZACCEL_HOST_KERNEL=blocked` runs).
    Scalar,
    /// Best ISA [`detect`] finds at runtime (the default).
    Auto,
    /// A specific ISA; falls back to scalar with a warning when the
    /// machine or build cannot run it.
    Force(Isa),
}

impl SimdSelect {
    /// Parse `scalar` | `auto` | `avx2` | `avx512` | `neon`.
    pub fn parse(s: &str) -> Option<SimdSelect> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" | "off" => Some(SimdSelect::Scalar),
            "auto" | "on" => Some(SimdSelect::Auto),
            other => Isa::parse(other).map(SimdSelect::Force),
        }
    }

    /// Resolve the policy to the ISA that will actually run here.
    pub fn resolve(self) -> Isa {
        match self {
            SimdSelect::Scalar => Isa::Scalar,
            SimdSelect::Auto => detect(),
            SimdSelect::Force(isa) => {
                if isa.available() {
                    isa
                } else {
                    // resolve() sits on the per-GEMM hot path (and runs
                    // again in the dispatcher's ISA accounting): warn
                    // once per process, not once per call.
                    static WARNED: std::sync::Once = std::sync::Once::new();
                    WARNED.call_once(|| {
                        log::warn!(
                            "requested SIMD ISA {:?} unavailable on this machine/build; \
                             falling back to scalar",
                            isa.name()
                        );
                    });
                    Isa::Scalar
                }
            }
        }
    }
}

struct ScalarKernel;

static SCALAR: ScalarKernel = ScalarKernel;

impl Microkernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }
    #[inline]
    fn run(&self, acc: &mut [[i32; NR_I8]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        microkernel::<i32>(acc, a_panel, b_panel);
    }
}

#[cfg(target_arch = "x86_64")]
struct Avx2Kernel;

#[cfg(target_arch = "x86_64")]
static AVX2: Avx2Kernel = Avx2Kernel;

#[cfg(target_arch = "x86_64")]
impl Microkernel for Avx2Kernel {
    fn name(&self) -> &'static str {
        "avx2"
    }
    #[inline]
    fn run(&self, acc: &mut [[i32; NR_I8]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        // Safety: this instance is only reachable through
        // `Isa::microkernel`, which verified AVX2 via CPUID.
        unsafe { x86::run_avx2(acc, a_panel, b_panel) }
    }
    #[inline]
    fn run_wide(&self, acc: &mut [[i32; NR_I8_WIDE]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        // Safety: as for `run`.
        unsafe { x86::run_avx2_wide(acc, a_panel, b_panel) }
    }
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
struct Avx512Kernel;

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
static AVX512: Avx512Kernel = Avx512Kernel;

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
impl Microkernel for Avx512Kernel {
    fn name(&self) -> &'static str {
        "avx512"
    }
    #[inline]
    fn run(&self, acc: &mut [[i32; NR_I8]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        // Safety: reachable only via `Isa::microkernel` after the
        // avx512vl+avx512vnni+avx512bw CPUID probe.
        unsafe { x86::run_avx512(acc, a_panel, b_panel) }
    }
    #[inline]
    fn run_wide(&self, acc: &mut [[i32; NR_I8_WIDE]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        // Safety: as for `run` (the wide body additionally uses
        // `vpermt2w`, covered by the avx512bw probe).
        unsafe { x86::run_avx512_wide(acc, a_panel, b_panel) }
    }
}

#[cfg(target_arch = "aarch64")]
struct NeonKernel;

#[cfg(target_arch = "aarch64")]
static NEON: NeonKernel = NeonKernel;

#[cfg(target_arch = "aarch64")]
impl Microkernel for NeonKernel {
    fn name(&self) -> &'static str {
        "neon"
    }
    #[inline]
    fn run(&self, acc: &mut [[i32; NR_I8]; MR_I8], a_panel: &[i8], b_panel: &[i8]) {
        // Safety: NEON is mandatory on aarch64.
        unsafe { neon::run_neon(acc, a_panel, b_panel) }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR_I8, NR_I8, NR_I8_WIDE};

    /// Two sign-extended `i8` values packed as the `(lo, hi)` `i16`
    /// halves of one `i32` lane — the broadcast operand of
    /// `vpmaddwd`/`vpdpwssd`.
    #[inline(always)]
    fn pair16(lo: i8, hi: i8) -> i32 {
        ((lo as i16 as u16 as u32) | ((hi as i16 as u16 as u32) << 16)) as i32
    }

    /// AVX2 microkernel body.  Processes two contraction steps per
    /// iteration: B columns for `p` and `p+1` are interleaved into
    /// `i16` pairs and `_mm256_madd_epi16` computes
    /// `a[p]·b[p] + a[p+1]·b[p+1]` per output lane in exact `i32`.
    ///
    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2,sse4.1")]
    pub(super) unsafe fn run_avx2(
        acc: &mut [[i32; NR_I8]; MR_I8],
        a_panel: &[i8],
        b_panel: &[i8],
    ) {
        use std::arch::x86_64::*;
        let k = b_panel.len() / NR_I8;
        debug_assert_eq!(a_panel.len(), k * MR_I8);
        debug_assert_eq!(b_panel.len(), k * NR_I8);
        let ap = a_panel.as_ptr();
        let bp = b_panel.as_ptr();
        let mut c0 = _mm256_loadu_si256(acc[0].as_ptr() as *const __m256i);
        let mut c1 = _mm256_loadu_si256(acc[1].as_ptr() as *const __m256i);
        let mut c2 = _mm256_loadu_si256(acc[2].as_ptr() as *const __m256i);
        let mut c3 = _mm256_loadu_si256(acc[3].as_ptr() as *const __m256i);
        let mut p = 0usize;
        while p + 2 <= k {
            let b0 = _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add(p * NR_I8) as *const __m128i));
            let b1 = _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add((p + 1) * NR_I8) as *const __m128i));
            let bpair =
                _mm256_set_m128i(_mm_unpackhi_epi16(b0, b1), _mm_unpacklo_epi16(b0, b1));
            let a0 = ap.add(p * MR_I8);
            let a1 = ap.add((p + 1) * MR_I8);
            c0 = _mm256_add_epi32(
                c0,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0, *a1)), bpair),
            );
            c1 = _mm256_add_epi32(
                c1,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0.add(1), *a1.add(1))), bpair),
            );
            c2 = _mm256_add_epi32(
                c2,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0.add(2), *a1.add(2))), bpair),
            );
            c3 = _mm256_add_epi32(
                c3,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0.add(3), *a1.add(3))), bpair),
            );
            p += 2;
        }
        if p < k {
            // Odd-K tail: pair the last step with zeros (0·x adds
            // nothing, exactly).
            let b0 = _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add(p * NR_I8) as *const __m128i));
            let z = _mm_setzero_si128();
            let bpair = _mm256_set_m128i(_mm_unpackhi_epi16(b0, z), _mm_unpacklo_epi16(b0, z));
            let a0 = ap.add(p * MR_I8);
            c0 = _mm256_add_epi32(
                c0,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0, 0)), bpair),
            );
            c1 = _mm256_add_epi32(
                c1,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0.add(1), 0)), bpair),
            );
            c2 = _mm256_add_epi32(
                c2,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0.add(2), 0)), bpair),
            );
            c3 = _mm256_add_epi32(
                c3,
                _mm256_madd_epi16(_mm256_set1_epi32(pair16(*a0.add(3), 0)), bpair),
            );
        }
        _mm256_storeu_si256(acc[0].as_mut_ptr() as *mut __m256i, c0);
        _mm256_storeu_si256(acc[1].as_mut_ptr() as *mut __m256i, c1);
        _mm256_storeu_si256(acc[2].as_mut_ptr() as *mut __m256i, c2);
        _mm256_storeu_si256(acc[3].as_mut_ptr() as *mut __m256i, c3);
    }

    /// AVX2 NR=16 wide-tile body: two 256-bit accumulator halves per
    /// row over B panels packed with tile width [`NR_I8_WIDE`], same
    /// paired-step `vpmaddwd` layout as [`run_avx2`].
    ///
    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2,sse4.1")]
    pub(super) unsafe fn run_avx2_wide(
        acc: &mut [[i32; NR_I8_WIDE]; MR_I8],
        a_panel: &[i8],
        b_panel: &[i8],
    ) {
        use std::arch::x86_64::*;
        let k = b_panel.len() / NR_I8_WIDE;
        debug_assert_eq!(a_panel.len(), k * MR_I8);
        debug_assert_eq!(b_panel.len(), k * NR_I8_WIDE);
        let ap = a_panel.as_ptr();
        let bp = b_panel.as_ptr();
        // acc[r] is 16 contiguous i32 = two ymm halves per row.
        let mut c: [[__m256i; 2]; MR_I8] = [[_mm256_setzero_si256(); 2]; MR_I8];
        for r in 0..MR_I8 {
            c[r][0] = _mm256_loadu_si256(acc[r].as_ptr() as *const __m256i);
            c[r][1] = _mm256_loadu_si256(acc[r].as_ptr().add(8) as *const __m256i);
        }
        let mut p = 0usize;
        while p < k {
            // Pair step p with p+1 (or with zeros on the odd-K tail).
            let b_lo0 =
                _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add(p * NR_I8_WIDE) as *const __m128i));
            let b_hi0 =
                _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add(p * NR_I8_WIDE + 8) as *const __m128i));
            let (b_lo1, b_hi1, a1) = if p + 1 < k {
                (
                    _mm_cvtepi8_epi16(_mm_loadl_epi64(
                        bp.add((p + 1) * NR_I8_WIDE) as *const __m128i
                    )),
                    _mm_cvtepi8_epi16(_mm_loadl_epi64(
                        bp.add((p + 1) * NR_I8_WIDE + 8) as *const __m128i,
                    )),
                    ap.add((p + 1) * MR_I8),
                )
            } else {
                (_mm_setzero_si128(), _mm_setzero_si128(), std::ptr::null())
            };
            let bpair_lo = _mm256_set_m128i(
                _mm_unpackhi_epi16(b_lo0, b_lo1),
                _mm_unpacklo_epi16(b_lo0, b_lo1),
            );
            let bpair_hi = _mm256_set_m128i(
                _mm_unpackhi_epi16(b_hi0, b_hi1),
                _mm_unpacklo_epi16(b_hi0, b_hi1),
            );
            let a0 = ap.add(p * MR_I8);
            for (r, cr) in c.iter_mut().enumerate() {
                let hi = if a1.is_null() { 0 } else { *a1.add(r) };
                let av = _mm256_set1_epi32(pair16(*a0.add(r), hi));
                cr[0] = _mm256_add_epi32(cr[0], _mm256_madd_epi16(av, bpair_lo));
                cr[1] = _mm256_add_epi32(cr[1], _mm256_madd_epi16(av, bpair_hi));
            }
            p += 2;
        }
        for r in 0..MR_I8 {
            _mm256_storeu_si256(acc[r].as_mut_ptr() as *mut __m256i, c[r][0]);
            _mm256_storeu_si256(acc[r].as_mut_ptr().add(8) as *mut __m256i, c[r][1]);
        }
    }

    /// AVX-512 VNNI microkernel body: identical pair layout to
    /// [`run_avx2`], with `_mm256_dpwssd_epi32` fusing the
    /// multiply-add-accumulate into one instruction.
    ///
    /// # Safety
    /// Caller must guarantee AVX-512VL + AVX-512VNNI availability.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512vnni,avx512vl,avx2,sse4.1")]
    pub(super) unsafe fn run_avx512(
        acc: &mut [[i32; NR_I8]; MR_I8],
        a_panel: &[i8],
        b_panel: &[i8],
    ) {
        use std::arch::x86_64::*;
        let k = b_panel.len() / NR_I8;
        debug_assert_eq!(a_panel.len(), k * MR_I8);
        let ap = a_panel.as_ptr();
        let bp = b_panel.as_ptr();
        let mut c0 = _mm256_loadu_si256(acc[0].as_ptr() as *const __m256i);
        let mut c1 = _mm256_loadu_si256(acc[1].as_ptr() as *const __m256i);
        let mut c2 = _mm256_loadu_si256(acc[2].as_ptr() as *const __m256i);
        let mut c3 = _mm256_loadu_si256(acc[3].as_ptr() as *const __m256i);
        let mut p = 0usize;
        while p + 2 <= k {
            let b0 = _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add(p * NR_I8) as *const __m128i));
            let b1 = _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add((p + 1) * NR_I8) as *const __m128i));
            let bpair =
                _mm256_set_m128i(_mm_unpackhi_epi16(b0, b1), _mm_unpacklo_epi16(b0, b1));
            let a0 = ap.add(p * MR_I8);
            let a1 = ap.add((p + 1) * MR_I8);
            c0 = _mm256_dpwssd_epi32(c0, _mm256_set1_epi32(pair16(*a0, *a1)), bpair);
            c1 = _mm256_dpwssd_epi32(c1, _mm256_set1_epi32(pair16(*a0.add(1), *a1.add(1))), bpair);
            c2 = _mm256_dpwssd_epi32(c2, _mm256_set1_epi32(pair16(*a0.add(2), *a1.add(2))), bpair);
            c3 = _mm256_dpwssd_epi32(c3, _mm256_set1_epi32(pair16(*a0.add(3), *a1.add(3))), bpair);
            p += 2;
        }
        if p < k {
            let b0 = _mm_cvtepi8_epi16(_mm_loadl_epi64(bp.add(p * NR_I8) as *const __m128i));
            let z = _mm_setzero_si128();
            let bpair = _mm256_set_m128i(_mm_unpackhi_epi16(b0, z), _mm_unpacklo_epi16(b0, z));
            let a0 = ap.add(p * MR_I8);
            c0 = _mm256_dpwssd_epi32(c0, _mm256_set1_epi32(pair16(*a0, 0)), bpair);
            c1 = _mm256_dpwssd_epi32(c1, _mm256_set1_epi32(pair16(*a0.add(1), 0)), bpair);
            c2 = _mm256_dpwssd_epi32(c2, _mm256_set1_epi32(pair16(*a0.add(2), 0)), bpair);
            c3 = _mm256_dpwssd_epi32(c3, _mm256_set1_epi32(pair16(*a0.add(3), 0)), bpair);
        }
        _mm256_storeu_si256(acc[0].as_mut_ptr() as *mut __m256i, c0);
        _mm256_storeu_si256(acc[1].as_mut_ptr() as *mut __m256i, c1);
        _mm256_storeu_si256(acc[2].as_mut_ptr() as *mut __m256i, c2);
        _mm256_storeu_si256(acc[3].as_mut_ptr() as *mut __m256i, c3);
    }

    /// `vpermw` index interleaving two 16-element i16 halves of a zmm
    /// into per-column `(b_p[c], b_{p+1}[c])` pairs: element `2c` picks
    /// `c` (from `b_p`), element `2c+1` picks `16 + c` (from `b_{p+1}`).
    #[cfg(feature = "avx512")]
    const IDX_PAIR: [i16; 32] = {
        let mut v = [0i16; 32];
        let mut c = 0usize;
        while c < 16 {
            v[2 * c] = c as i16;
            v[2 * c + 1] = 16 + c as i16;
            c += 1;
        }
        v
    };

    /// AVX-512 NR=16 native-width body: one 512-bit accumulator row per
    /// register-tile row, `vpermw` zipping the two contraction steps'
    /// B columns into i16 pairs and `vpdpwssd` fusing the
    /// multiply-add-accumulate — the full-width tile the autotuner can
    /// select where it measures faster than two 256-bit passes.
    ///
    /// # Safety
    /// Caller must guarantee AVX-512F/BW/VL + AVX-512VNNI availability.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f,avx512bw,avx512vnni,avx512vl,avx2,sse4.1")]
    pub(super) unsafe fn run_avx512_wide(
        acc: &mut [[i32; NR_I8_WIDE]; MR_I8],
        a_panel: &[i8],
        b_panel: &[i8],
    ) {
        use std::arch::x86_64::*;
        let k = b_panel.len() / NR_I8_WIDE;
        debug_assert_eq!(a_panel.len(), k * MR_I8);
        debug_assert_eq!(b_panel.len(), k * NR_I8_WIDE);
        let ap = a_panel.as_ptr();
        let bp = b_panel.as_ptr();
        let idx: __m512i = std::mem::transmute(IDX_PAIR);
        let mut c: [__m512i; MR_I8] = [
            _mm512_loadu_si512(acc[0].as_ptr() as *const _),
            _mm512_loadu_si512(acc[1].as_ptr() as *const _),
            _mm512_loadu_si512(acc[2].as_ptr() as *const _),
            _mm512_loadu_si512(acc[3].as_ptr() as *const _),
        ];
        let mut p = 0usize;
        while p < k {
            let b0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                bp.add(p * NR_I8_WIDE) as *const __m128i
            ));
            let (b1, a1) = if p + 1 < k {
                (
                    _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        bp.add((p + 1) * NR_I8_WIDE) as *const __m128i,
                    )),
                    ap.add((p + 1) * MR_I8),
                )
            } else {
                (_mm256_setzero_si256(), std::ptr::null())
            };
            let both = _mm512_inserti64x4(_mm512_castsi256_si512(b0), b1, 1);
            let bpair = _mm512_permutexvar_epi16(idx, both);
            let a0 = ap.add(p * MR_I8);
            for (r, cr) in c.iter_mut().enumerate() {
                let hi = if a1.is_null() { 0 } else { *a1.add(r) };
                *cr = _mm512_dpwssd_epi32(*cr, _mm512_set1_epi32(pair16(*a0.add(r), hi)), bpair);
            }
            p += 2;
        }
        for r in 0..MR_I8 {
            _mm512_storeu_si512(acc[r].as_mut_ptr() as *mut _, c[r]);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{MR_I8, NR_I8};

    /// NEON microkernel body: per contraction step, `vmull_s8` widens
    /// the `i8` products to `i16x8` and two widening adds fold them
    /// into the `i32` accumulators — every operation exact.
    ///
    /// # Safety
    /// NEON must be available (always true on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn run_neon(
        acc: &mut [[i32; NR_I8]; MR_I8],
        a_panel: &[i8],
        b_panel: &[i8],
    ) {
        use std::arch::aarch64::*;
        let k = b_panel.len() / NR_I8;
        debug_assert_eq!(a_panel.len(), k * MR_I8);
        let ap = a_panel.as_ptr();
        let bp = b_panel.as_ptr();
        let mut clo = [
            vld1q_s32(acc[0].as_ptr()),
            vld1q_s32(acc[1].as_ptr()),
            vld1q_s32(acc[2].as_ptr()),
            vld1q_s32(acc[3].as_ptr()),
        ];
        let mut chi = [
            vld1q_s32(acc[0].as_ptr().add(4)),
            vld1q_s32(acc[1].as_ptr().add(4)),
            vld1q_s32(acc[2].as_ptr().add(4)),
            vld1q_s32(acc[3].as_ptr().add(4)),
        ];
        for p in 0..k {
            let bv = vld1_s8(bp.add(p * NR_I8));
            for r in 0..MR_I8 {
                let av = vdup_n_s8(*ap.add(p * MR_I8 + r));
                let prod = vmull_s8(av, bv);
                clo[r] = vaddw_s16(clo[r], vget_low_s16(prod));
                chi[r] = vaddw_s16(chi[r], vget_high_s16(prod));
            }
        }
        for r in 0..MR_I8 {
            vst1q_s32(acc[r].as_mut_ptr(), clo[r]);
            vst1q_s32(acc[r].as_mut_ptr().add(4), chi[r]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn rand_panels(rng: &mut Rng, k: usize) -> (Vec<i8>, Vec<i8>) {
        let a: Vec<i8> = (0..k * MR_I8)
            .map(|_| (rng.index(0, 255) as i32 - 127) as i8)
            .collect();
        let b: Vec<i8> = (0..k * NR_I8)
            .map(|_| (rng.index(0, 255) as i32 - 127) as i8)
            .collect();
        (a, b)
    }

    #[test]
    fn every_available_isa_matches_scalar_bitwise() {
        let mut rng = Rng::new(0x51D);
        // Odd and even K exercise the paired-step tail handling.
        for k in [0usize, 1, 2, 3, 7, 8, 33, 64, 129] {
            let (a, b) = rand_panels(&mut rng, k);
            let mut want = [[123i32; NR_I8]; MR_I8]; // nonzero: += not =
            SCALAR.run(&mut want, &a, &b);
            for isa in available_isas() {
                let mut got = [[123i32; NR_I8]; MR_I8];
                isa.microkernel().run(&mut got, &a, &b);
                assert_eq!(got, want, "isa={} k={k}", isa.name());
            }
        }
    }

    #[test]
    fn every_available_isa_matches_scalar_bitwise_on_the_wide_tile() {
        // Same bar for the NR=16 tile: the AVX2 two-half body and the
        // AVX-512 zmm body must reproduce the scalar oracle's bits,
        // including the zero-paired odd-K tail.
        let mut rng = Rng::new(0x16D);
        for k in [0usize, 1, 2, 3, 7, 8, 33, 64, 129] {
            let a: Vec<i8> = (0..k * MR_I8)
                .map(|_| (rng.index(0, 255) as i32 - 127) as i8)
                .collect();
            let b: Vec<i8> = (0..k * NR_I8_WIDE)
                .map(|_| (rng.index(0, 255) as i32 - 127) as i8)
                .collect();
            let mut want = [[321i32; NR_I8_WIDE]; MR_I8]; // nonzero: += not =
            SCALAR.run_wide(&mut want, &a, &b);
            for isa in available_isas() {
                let mut got = [[321i32; NR_I8_WIDE]; MR_I8];
                isa.microkernel().run_wide(&mut got, &a, &b);
                assert_eq!(got, want, "isa={} k={k}", isa.name());
            }
        }
    }

    #[test]
    fn saturated_inputs_stay_exact_on_the_wide_tile() {
        let k = 1000usize;
        let a = vec![127i8; k * MR_I8];
        let b = vec![-127i8; k * NR_I8_WIDE];
        for isa in available_isas() {
            let mut acc = [[0i32; NR_I8_WIDE]; MR_I8];
            isa.microkernel().run_wide(&mut acc, &a, &b);
            for row in &acc {
                for &v in row {
                    assert_eq!(v, -(k as i32) * 127 * 127, "isa={}", isa.name());
                }
            }
        }
    }

    #[test]
    fn saturated_inputs_stay_exact_on_every_isa() {
        // Worst-case ±127 panels: the largest per-step magnitudes the
        // pair instructions must represent without saturating.
        let k = 1000usize;
        let a = vec![127i8; k * MR_I8];
        let b = vec![-127i8; k * NR_I8];
        for isa in available_isas() {
            let mut acc = [[0i32; NR_I8]; MR_I8];
            isa.microkernel().run(&mut acc, &a, &b);
            for row in &acc {
                for &v in row {
                    assert_eq!(v, -(k as i32) * 127 * 127, "isa={}", isa.name());
                }
            }
        }
    }

    #[test]
    fn detect_and_selects_resolve_sanely() {
        assert!(detect().available());
        assert_eq!(SimdSelect::Scalar.resolve(), Isa::Scalar);
        assert_eq!(SimdSelect::Auto.resolve(), detect());
        // Forcing an unavailable ISA falls back to scalar instead of
        // executing illegal instructions.
        for isa in [Isa::Avx2, Isa::Avx512, Isa::Neon] {
            let r = SimdSelect::Force(isa).resolve();
            if isa.available() {
                assert_eq!(r, isa);
            } else {
                assert_eq!(r, Isa::Scalar);
            }
        }
        assert!(available_isas().contains(&Isa::Scalar));
    }

    #[test]
    fn parse_names_roundtrip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(SimdSelect::parse("auto"), Some(SimdSelect::Auto));
        assert_eq!(SimdSelect::parse("SCALAR"), Some(SimdSelect::Scalar));
        assert_eq!(SimdSelect::parse("avx2"), Some(SimdSelect::Force(Isa::Avx2)));
        assert_eq!(SimdSelect::parse("mmx"), None);
        assert_eq!(Isa::parse("sse9"), None);
    }
}
