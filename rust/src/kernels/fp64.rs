//! Blocked, threaded FP64 and complex GEMM on the packed-panel
//! infrastructure.
//!
//! The real kernel keeps a `MR_F64 x NR_F64` register tile of partial
//! sums; per `p` it broadcasts packed A values against packed B values.
//! Every output element is accumulated in ascending-`p` order by a
//! single accumulator, so `dgemm_blocked` is bit-for-bit identical to
//! the textbook `dgemm_naive` loop at any blocking factor or thread
//! count — the runtime's padding/bucketing policies rely on that
//! determinism.
//!
//! The complex kernel packs re/im planes once and fuses the four real
//! products of the ozIMMU decomposition (`Cre = Ar·Br − Ai·Bi`,
//! `Cim = Ar·Bi + Ai·Br`) into one sweep over the shared panels.

use super::pack::{pack_cols_c64_mt, pack_cols_f64_mt, pack_rows_c64_mt, pack_rows_f64_mt, Panels};
use super::{run_bands, KernelConfig};
use crate::complex::c64;
use crate::error::{Error, Result};
use crate::linalg::{Mat, ZMat};

/// Rows per FP64 register tile.
pub const MR_F64: usize = 4;
/// Columns per FP64 register tile.
pub const NR_F64: usize = 4;
/// Rows per complex register tile (four accumulator tiles live at once,
/// so the tile is narrower to stay within the register file).
pub const MR_C64: usize = 2;
/// Columns per complex register tile.
pub const NR_C64: usize = 4;

#[inline]
fn microkernel_f64(acc: &mut [[f64; NR_F64]; MR_F64], a_panel: &[f64], b_panel: &[f64]) {
    for (av, bv) in a_panel.chunks_exact(MR_F64).zip(b_panel.chunks_exact(NR_F64)) {
        for r in 0..MR_F64 {
            let ar = av[r];
            let row = &mut acc[r];
            for c in 0..NR_F64 {
                row[c] += ar * bv[c];
            }
        }
    }
}

/// Blocked + threaded host FP64 GEMM (bit-for-bit equal to
/// [`crate::linalg::dgemm_naive`]).
pub fn dgemm_blocked(a: &Mat<f64>, b: &Mat<f64>, cfg: &KernelConfig) -> Result<Mat<f64>> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "dgemm: {}x{} @ {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let (m, n) = (a.rows(), b.cols());
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return Ok(c);
    }
    let ap = pack_rows_f64_mt(a, MR_F64, cfg.pack_threads());
    let bp = pack_cols_f64_mt(b, NR_F64, cfg.pack_threads());

    run_bands(
        c.data_mut(),
        n,
        MR_F64,
        ap.tiles(),
        cfg.threads,
        |band, tile0| f64_band(band, tile0, n, &ap, &bp, cfg),
    );
    Ok(c)
}

fn f64_band(
    c_band: &mut [f64],
    tile0: usize,
    n: usize,
    ap: &Panels<f64>,
    bp: &Panels<f64>,
    cfg: &KernelConfig,
) {
    let band_rows = c_band.len() / n;
    let band_tiles = band_rows.div_ceil(MR_F64);
    let k = ap.k();
    let kc = cfg.kc.max(1);
    let nc_tiles = (cfg.nc / NR_F64).max(1);
    let n_tiles = bp.tiles();

    for jc in (0..n_tiles).step_by(nc_tiles) {
        let jc_end = (jc + nc_tiles).min(n_tiles);
        for it in 0..band_tiles {
            let row0 = it * MR_F64;
            let ilim = MR_F64.min(band_rows - row0);
            let apan = ap.panel(0, tile0 + it);
            for jt in jc..jc_end {
                let col0 = jt * NR_F64;
                let jlim = NR_F64.min(n - col0);
                let bpan = bp.panel(0, jt);
                let mut acc = [[0.0f64; NR_F64]; MR_F64];
                let mut k0 = 0;
                while k0 < k {
                    let k1 = (k0 + kc).min(k);
                    microkernel_f64(
                        &mut acc,
                        &apan[k0 * MR_F64..k1 * MR_F64],
                        &bpan[k0 * NR_F64..k1 * NR_F64],
                    );
                    k0 = k1;
                }
                for r in 0..ilim {
                    let base = (row0 + r) * n + col0;
                    for (dst, src) in c_band[base..base + jlim].iter_mut().zip(&acc[r]) {
                        *dst = *src;
                    }
                }
            }
        }
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn microkernel_c64(
    rr: &mut [[f64; NR_C64]; MR_C64],
    ri: &mut [[f64; NR_C64]; MR_C64],
    ir: &mut [[f64; NR_C64]; MR_C64],
    ii: &mut [[f64; NR_C64]; MR_C64],
    ar: &[f64],
    ai: &[f64],
    br: &[f64],
    bi: &[f64],
) {
    let a_iter = ar.chunks_exact(MR_C64).zip(ai.chunks_exact(MR_C64));
    let b_iter = br.chunks_exact(NR_C64).zip(bi.chunks_exact(NR_C64));
    for ((avr, avi), (bvr, bvi)) in a_iter.zip(b_iter) {
        for r in 0..MR_C64 {
            let xr = avr[r];
            let xi = avi[r];
            for c in 0..NR_C64 {
                rr[r][c] += xr * bvr[c];
                ri[r][c] += xr * bvi[c];
                ir[r][c] += xi * bvr[c];
                ii[r][c] += xi * bvi[c];
            }
        }
    }
}

/// Blocked + threaded complex GEMM: re/im planes packed once, the four
/// real products fused into one sweep over the shared panels.
pub fn zgemm_blocked(a: &ZMat, b: &ZMat, cfg: &KernelConfig) -> Result<ZMat> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "zgemm: {}x{} @ {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let (m, n) = (a.rows(), b.cols());
    let mut c = ZMat::zeros(m, n);
    if m == 0 || n == 0 {
        return Ok(c);
    }
    let (apr_re, apr_im) = pack_rows_c64_mt(a, MR_C64, cfg.pack_threads());
    let (bpr_re, bpr_im) = pack_cols_c64_mt(b, NR_C64, cfg.pack_threads());

    run_bands(
        c.data_mut(),
        n,
        MR_C64,
        apr_re.tiles(),
        cfg.threads,
        |band, tile0| z64_band(band, tile0, n, &apr_re, &apr_im, &bpr_re, &bpr_im, cfg),
    );
    Ok(c)
}

#[allow(clippy::too_many_arguments)]
fn z64_band(
    c_band: &mut [c64],
    tile0: usize,
    n: usize,
    are: &Panels<f64>,
    aim: &Panels<f64>,
    bre: &Panels<f64>,
    bim: &Panels<f64>,
    cfg: &KernelConfig,
) {
    let band_rows = c_band.len() / n;
    let band_tiles = band_rows.div_ceil(MR_C64);
    let k = are.k();
    let kc = cfg.kc.max(1);
    let nc_tiles = (cfg.nc / NR_C64).max(1);
    let n_tiles = bre.tiles();

    for jc in (0..n_tiles).step_by(nc_tiles) {
        let jc_end = (jc + nc_tiles).min(n_tiles);
        for it in 0..band_tiles {
            let row0 = it * MR_C64;
            let ilim = MR_C64.min(band_rows - row0);
            let ap_re = are.panel(0, tile0 + it);
            let ap_im = aim.panel(0, tile0 + it);
            for jt in jc..jc_end {
                let col0 = jt * NR_C64;
                let jlim = NR_C64.min(n - col0);
                let bp_re = bre.panel(0, jt);
                let bp_im = bim.panel(0, jt);
                let mut rr = [[0.0f64; NR_C64]; MR_C64];
                let mut ri = [[0.0f64; NR_C64]; MR_C64];
                let mut ir = [[0.0f64; NR_C64]; MR_C64];
                let mut ii = [[0.0f64; NR_C64]; MR_C64];
                let mut k0 = 0;
                while k0 < k {
                    let k1 = (k0 + kc).min(k);
                    microkernel_c64(
                        &mut rr,
                        &mut ri,
                        &mut ir,
                        &mut ii,
                        &ap_re[k0 * MR_C64..k1 * MR_C64],
                        &ap_im[k0 * MR_C64..k1 * MR_C64],
                        &bp_re[k0 * NR_C64..k1 * NR_C64],
                        &bp_im[k0 * NR_C64..k1 * NR_C64],
                    );
                    k0 = k1;
                }
                for r in 0..ilim {
                    let base = (row0 + r) * n + col0;
                    for (cc, dst) in c_band[base..base + jlim].iter_mut().enumerate() {
                        *dst = c64(rr[r][cc] - ii[r][cc], ri[r][cc] + ir[r][cc]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dgemm_naive, zgemm_naive};
    use crate::testing::Rng;

    #[test]
    fn dgemm_blocked_is_bit_identical_to_naive() {
        let mut rng = Rng::new(0xF64);
        for (m, k, n) in [(1, 1, 1), (4, 4, 4), (5, 3, 6), (13, 17, 9), (40, 7, 2)] {
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let want = dgemm_naive(&a, &b).unwrap();
            for threads in [1usize, 3] {
                let cfg = KernelConfig {
                    threads,
                    kc: 5,
                    ..KernelConfig::default()
                };
                let got = dgemm_blocked(&a, &b, &cfg).unwrap();
                assert_eq!(got.data(), want.data(), "{m}x{k}x{n} threads={threads}");
            }
        }
    }

    #[test]
    fn zgemm_blocked_matches_naive() {
        let mut rng = Rng::new(0xC64);
        for (m, k, n) in [(1, 1, 1), (2, 4, 4), (5, 3, 6), (9, 12, 7)] {
            let a = Mat::from_fn(m, k, |_, _| rng.cnormal());
            let b = Mat::from_fn(k, n, |_, _| rng.cnormal());
            let want = zgemm_naive(&a, &b).unwrap();
            let scale = want.data().iter().fold(0.0f64, |mx, z| mx.max(z.abs())) + 1e-300;
            for threads in [1usize, 4] {
                let cfg = KernelConfig {
                    threads,
                    ..KernelConfig::default()
                };
                let got = zgemm_blocked(&a, &b, &cfg).unwrap();
                for (x, y) in got.data().iter().zip(want.data()) {
                    assert!((*x - *y).abs() <= 1e-12 * scale, "{m}x{k}x{n}: {x:?} vs {y:?}");
                }
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Mat::<f64>::zeros(3, 4);
        let b = Mat::<f64>::zeros(5, 2);
        assert!(dgemm_blocked(&a, &b, &KernelConfig::default()).is_err());
        let za = ZMat::zeros(3, 4);
        let zb = ZMat::zeros(5, 2);
        assert!(zgemm_blocked(&za, &zb, &KernelConfig::default()).is_err());
    }

    #[test]
    fn degenerate_dimensions() {
        let a = Mat::<f64>::zeros(0, 3);
        let b = Mat::<f64>::zeros(3, 4);
        let c = dgemm_blocked(&a, &b, &KernelConfig::default()).unwrap();
        assert_eq!((c.rows(), c.cols()), (0, 4));
        let a2 = Mat::<f64>::zeros(2, 0);
        let b2 = Mat::<f64>::zeros(0, 3);
        let c2 = dgemm_blocked(&a2, &b2, &KernelConfig::default()).unwrap();
        assert!(c2.data().iter().all(|v| *v == 0.0));
    }
}
