//! SplitMix64 — the crate's one deterministic PRNG and bit mixer.
//!
//! # Stability contract
//!
//! This generator is **runtime infrastructure**, not just test support.
//! Three consumers depend on its exact output sequence:
//!
//! * the precision governor's probe row sampling
//!   ([`crate::precision::sample_rows`]) derives its documented
//!   cross-thread bit-determinism from this sequence — a changed
//!   constant silently changes which output rows production probes
//!   recompute;
//! * the packed-panel cache digest
//!   ([`crate::kernels::panel_cache::fingerprint`]) folds every operand
//!   word through the same finalizer ([`mix64`]) — its collision
//!   argument (full per-word avalanche, so small-integer-valued
//!   matrices cannot collide on degenerate low bits) is an argument
//!   about *these* xor-shift/multiply constants;
//! * the property-test harness (`crate::testing::for_cases`) replays
//!   failures by seed.
//!
//! Accordingly: the constants, the state update, and the
//! seed/`index`/`uniform` mappings must not change.  Behaviour is
//! pinned by `tests/precision_governor.rs` (probe determinism), the
//! panel-cache digest tests, and the unit tests below.  If a different
//! generator is ever needed, add it alongside — do not edit this one.

use crate::complex::c64;

/// The SplitMix64 finalizer: full-avalanche mix of one 64-bit word.
///
/// Shared verbatim by [`Rng::next_u64`] and the panel-cache content
/// digest, so the avalanche property both rely on has a single home.
#[inline]
pub fn mix64(state: u64) -> u64 {
    let mut z = state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// SplitMix64 PRNG — deterministic, seedable, passes BigCrush for our
/// purposes, and has no dependencies.  See the module docs for the
/// stability contract.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (same seed, same sequence).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix64(self.state)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi).
    pub fn index(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard complex normal.
    pub fn cnormal(&mut self) -> c64 {
        c64(self.normal(), self.normal()) * std::f64::consts::FRAC_1_SQRT_2
    }

    /// Vector of normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Value with a wide dynamic range: normal mantissa, random binary
    /// exponent in [-emax, emax].  Stresses the scaling logic.
    pub fn wide(&mut self, emax: i32) -> f64 {
        let e = self.index(0, (2 * emax + 1) as usize) as i32 - emax;
        let m = self.normal();
        m * (e as f64).exp2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn sequence_is_pinned() {
        // The stability contract in concrete numbers: the first outputs
        // of seeds 0 and 1 must never change (probe sampling and the
        // cache digest both inherit from this exact sequence).
        let mut r0 = Rng::new(0);
        assert_eq!(r0.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r0.next_u64(), 0x06C45D188009454F);
        let mut r1 = Rng::new(1);
        assert_eq!(r1.next_u64(), 0xBEEB8DA1658EEC67);
    }

    #[test]
    fn mix64_matches_next_u64() {
        // next_u64 must be exactly "advance by golden gamma, mix64" —
        // the decomposition the panel-cache digest shares.
        let seed = 0xDEADBEEFu64;
        let mut r = Rng::new(seed);
        let want = mix64(
            seed.wrapping_add(0x9E3779B97F4A7C15)
                .wrapping_add(0x9E3779B97F4A7C15),
        );
        assert_eq!(r.next_u64(), want);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn wide_covers_exponents() {
        let mut r = Rng::new(3);
        let (mut small, mut big) = (false, false);
        for _ in 0..1000 {
            let x = r.wide(30).abs();
            if x != 0.0 && x < 1e-6 {
                small = true;
            }
            if x > 1e6 {
                big = true;
            }
        }
        assert!(small && big);
    }
}
