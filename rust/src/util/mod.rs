//! Small shared utilities with cross-subsystem stability contracts.
//!
//! [`rng`]: the SplitMix64 generator started life as test support in
//! `crate::testing`, but probe sampling and the panel-cache digest made
//! its exact bit sequence load-bearing at runtime, so it lives here
//! where the contract can be stated once and depended on from both
//! sides.
//!
//! [`env`]: the one loud way to read `OZACCEL_*` variables outside the
//! config file parser — malformed values abort with a uniform message
//! instead of each call site inventing its own silent fallback.

pub mod env;
pub mod rng;

pub use rng::{mix64, Rng};
