//! Small shared utilities with cross-subsystem stability contracts.
//!
//! The only resident today is [`rng`]: the SplitMix64 generator started
//! life as test support in `crate::testing`, but probe sampling and the
//! panel-cache digest made its exact bit sequence load-bearing at
//! runtime, so it lives here where the contract can be stated once and
//! depended on from both sides.

pub mod rng;

pub use rng::{mix64, Rng};
