//! Loud, uniform `OZACCEL_*` environment parsing.
//!
//! Every env knob read outside `config::RunConfig::apply_env`
//! historically had its own ad-hoc reaction to malformed values — some
//! logged a warning and kept the default, `OZACCEL_THREADS` was
//! silently ignored.  A typo like `OZACCEL_BATCH_MAX_BYTES=junk` then
//! ran with the default bound as if nothing were wrong, which is
//! exactly the failure mode a robustness layer must not have.  These
//! helpers make every such read fail one way: a panic naming the
//! variable, the rejected value, and the accepted form.  (Unset
//! variables are still simply absent — only *malformed* values are
//! fatal.)

/// Abort with the uniform malformed-env message.  Shared by
/// [`parse_env`] and by sites whose values go through a domain parser
/// (`HostKernel::parse`, `SimdSelect::parse`, ...) instead of
/// [`std::str::FromStr`].
pub fn invalid(name: &str, raw: &str, expected: &str) -> ! {
    panic!("ozaccel: invalid {name}={raw:?} (expected {expected})")
}

/// Read and parse `name`: `None` when unset, `Some(parsed)` when the
/// trimmed value parses, and a loud uniform panic otherwise.
/// `expected` describes the accepted form (e.g. `"a positive
/// integer"`).
pub fn parse_env<T: std::str::FromStr>(name: &str, expected: &str) -> Option<T> {
    let raw = std::env::var(name).ok()?;
    match raw.trim().parse::<T>() {
        Ok(v) => Some(v),
        Err(_) => invalid(name, &raw, expected),
    }
}

/// [`parse_env`] with a post-parse validity check; a parsed value the
/// check rejects fails with the same uniform message.
pub fn parse_env_checked<T: std::str::FromStr>(
    name: &str,
    expected: &str,
    ok: impl Fn(&T) -> bool,
) -> Option<T> {
    let raw = std::env::var(name).ok()?;
    match raw.trim().parse::<T>() {
        Ok(v) if ok(&v) => Some(v),
        _ => invalid(name, &raw, expected),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::env_lock;

    #[test]
    fn unset_is_none_and_valid_parses() {
        let _guard = env_lock();
        assert_eq!(parse_env::<usize>("OZACCEL_TEST_ENV_UNSET", "int"), None);
        std::env::set_var("OZACCEL_TEST_ENV_OK", " 42 ");
        assert_eq!(parse_env::<usize>("OZACCEL_TEST_ENV_OK", "int"), Some(42));
        std::env::remove_var("OZACCEL_TEST_ENV_OK");
    }

    #[test]
    fn malformed_values_panic_with_the_uniform_message() {
        let _guard = env_lock();
        std::env::set_var("OZACCEL_TEST_ENV_BAD", "junk");
        let err = std::panic::catch_unwind(|| {
            parse_env::<usize>("OZACCEL_TEST_ENV_BAD", "a positive integer")
        })
        .expect_err("malformed value must panic");
        std::env::remove_var("OZACCEL_TEST_ENV_BAD");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(
            msg.contains("invalid OZACCEL_TEST_ENV_BAD=\"junk\"")
                && msg.contains("a positive integer"),
            "message not uniform: {msg}"
        );
    }

    #[test]
    fn checked_rejects_out_of_domain_values() {
        let _guard = env_lock();
        std::env::set_var("OZACCEL_TEST_ENV_ZERO", "0");
        let caught = std::panic::catch_unwind(|| {
            parse_env_checked::<usize>("OZACCEL_TEST_ENV_ZERO", ">= 1", |&v| v >= 1)
        });
        std::env::remove_var("OZACCEL_TEST_ENV_ZERO");
        assert!(caught.is_err(), "0 must be rejected by the >= 1 check");
    }
}
