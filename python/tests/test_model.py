"""L2 model properties: emulation accuracy decays with splits, scaling
invariances hold, and the model agrees with the un-tiled oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([16, 32, 64]), k=st.sampled_from([16, 32, 64]),
       n=st.sampled_from([16, 32, 64]), splits=st.integers(3, 8),
       seed=st.integers(0, 2**31 - 1))
def test_model_matches_oracle(m, k, n, splits, seed):
    """Pallas-kernel model == un-tiled jnp oracle, bit-for-bit."""
    rng = np.random.default_rng(seed)
    a, b = rand(rng, (m, k)), rand(rng, (k, n))
    got = model.ozaki_dgemm(a, b, splits)
    want = ref.ozaki_dgemm_ref(a, b, splits)
    assert bool(jnp.all(got == want))


def test_accuracy_decays_with_splits():
    """~100x error reduction per extra split until the FP64 floor (the
    paper's Table 1 pattern)."""
    rng = np.random.default_rng(3)
    a, b = rand(rng, (64, 64)), rand(rng, (64, 64))
    cref = np.asarray(a) @ np.asarray(b)
    scale = float(np.max(np.abs(cref)))
    errs = []
    for s in range(3, 10):
        c = model.ozaki_dgemm(a, b, s)
        errs.append(float(jnp.max(jnp.abs(c - cref))) / scale)
    # at least 30x per split while above the FP64 floor
    for e, e_next in zip(errs[:-1], errs[1:]):
        if e > 1e-13:
            assert e_next < e / 30
    assert errs[-1] < 1e-13  # s=9 is at the FP64 floor
    assert errs[0] < 1e-4    # s=3 on well-conditioned data


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(-30, 30))
def test_power_of_two_scaling_invariance(seed, p):
    """C(2^p A, B) == 2^p C(A, B) exactly: scaling is pure exponent math."""
    rng = np.random.default_rng(seed)
    a, b = rand(rng, (16, 16)), rand(rng, (16, 16))
    c1 = ref.ozaki_dgemm_ref(a * (2.0 ** p), b, 5)
    c2 = ref.ozaki_dgemm_ref(a, b, 5) * (2.0 ** p)
    assert bool(jnp.all(c1 == c2))


def test_wide_dynamic_range_rows():
    """Rowwise scaling keeps accuracy when row magnitudes differ by 2^40."""
    rng = np.random.default_rng(5)
    a = np.array(rand(rng, (32, 32)))  # writable copy
    a[::2] *= 2.0 ** 40
    b = rand(rng, (32, 32))
    c = ref.ozaki_dgemm_ref(jnp.asarray(a), b, 7)
    cref = a @ np.asarray(b)
    # Rowwise normalisation: each row has its own scale (2^40 apart), so
    # a global max would hide the small rows entirely.
    row_scale = np.max(np.abs(cref), axis=1, keepdims=True)
    rel = float(np.max(np.abs(np.asarray(c) - cref) / row_scale))
    assert rel < 1e-11


def test_zero_matrix():
    z = jnp.zeros((16, 16))
    b = rand(np.random.default_rng(0), (16, 16))
    assert bool(jnp.all(ref.ozaki_dgemm_ref(z, b, 4) == 0.0))
    assert bool(jnp.all(ref.ozaki_dgemm_ref(b, z, 4) == 0.0))


def test_identity_matrix():
    rng = np.random.default_rng(1)
    b = rand(rng, (32, 32))
    c = ref.ozaki_dgemm_ref(jnp.eye(32), b, 8)
    assert float(jnp.max(jnp.abs(c - b))) < 1e-13


def test_zgemm_decomposition():
    """4-real-GEMM complex product matches numpy complex matmul."""
    rng = np.random.default_rng(2)
    ar, ai = rand(rng, (24, 24)), rand(rng, (24, 24))
    br, bi = rand(rng, (24, 24)), rand(rng, (24, 24))
    cre, cim = ref.zgemm_via_dgemm_ref(ar, ai, br, bi, splits=8)
    want = (np.asarray(ar) + 1j * np.asarray(ai)) @ (
        np.asarray(br) + 1j * np.asarray(bi))
    got = np.asarray(cre) + 1j * np.asarray(cim)
    assert np.max(np.abs(got - want)) / np.max(np.abs(want)) < 1e-13


def test_native_dgemm_entry():
    rng = np.random.default_rng(4)
    a, b = rand(rng, (32, 16)), rand(rng, (16, 8))
    (c,) = model.make_entry("dgemm", None)(a, b)
    assert np.allclose(np.asarray(c), np.asarray(a) @ np.asarray(b))


def test_make_entry_rejects_unknown():
    with pytest.raises(ValueError):
        model.make_entry("sgemm", None)
    with pytest.raises(AssertionError):
        model.make_entry("ozdg", 1)


def test_conditioning_amplifies_error():
    """The paper's §4 observation: near-singular consumers amplify the
    emulation error.  Solve A X = B with A increasingly ill-conditioned
    using the emulated product inside a residual check."""
    rng = np.random.default_rng(9)
    n = 32
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    errs = []
    for cond in (1e1, 1e6):
        dvals = np.logspace(0, -np.log10(cond), n)
        a = q @ np.diag(dvals) @ q.T
        ainv = np.linalg.inv(a)
        prod = ref.ozaki_dgemm_ref(jnp.asarray(a), jnp.asarray(ainv), 4)
        errs.append(float(jnp.max(jnp.abs(prod - np.eye(n)))))
    assert errs[1] > errs[0] * 10  # ill-conditioned case is visibly worse
