"""AOT driver smoke tests: HLO text emission, manifest format, naming."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402


def test_artifact_names():
    assert aot.artifact_name("dgemm", 0, 64, 64, 64) == "dgemm_64x64x64.hlo.txt"
    assert aot.artifact_name("ozdg", 6, 256, 64, 128) == \
        "ozdg_s6_256x64x128.hlo.txt"


def test_shape_set_covers_must_lu():
    """Every trailing-update bucket of the dim-256 / NB-64 blocked LU has
    an artifact shape."""
    for m in (64, 128, 256):
        for n in (64, 128, 256):
            assert (m, 64, n) in aot.MUST_SHAPES


def test_lower_one_emits_parsable_hlo():
    with tempfile.TemporaryDirectory() as d:
        name, nbytes, _ = aot.lower_one(("ozdg", 3, 16, 16, 16, d))
        text = open(os.path.join(d, name)).read()
        assert nbytes == len(text)
        assert "HloModule" in text
        assert "f64" in text      # FP64 I/O preserved
        assert "s8" in text       # INT8 slices present
        assert "s32" in text      # INT32 accumulation present


def test_lower_dgemm_native():
    with tempfile.TemporaryDirectory() as d:
        name, _, _ = aot.lower_one(("dgemm", 0, 8, 8, 8, d))
        text = open(os.path.join(d, name)).read()
        assert "HloModule" in text and "f64" in text
        assert "s8" not in text   # native path has no INT8


def test_manifest_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        n = aot.write_manifest(d, quick=True)
        lines = [ln for ln in open(os.path.join(d, "manifest.txt"))
                 if ln.strip() and not ln.startswith("#")]
        assert len(lines) == n
        for ln in lines:
            kind, s, m, k, nn, fname = ln.split()
            assert kind in ("dgemm", "ozdg")
            assert fname == aot.artifact_name(kind, int(s), int(m), int(k),
                                              int(nn))


def test_lowered_module_executes():
    """The HLO we ship actually runs (via jax runtime) and is accurate."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((16, 16)))
    b = jnp.asarray(rng.standard_normal((16, 16)))
    (c,) = jax.jit(model.make_entry("ozdg", 6))(a, b)
    want = np.asarray(a) @ np.asarray(b)
    assert np.max(np.abs(np.asarray(c) - want)) / np.max(np.abs(want)) < 1e-11
