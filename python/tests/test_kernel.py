"""L1 kernel vs pure-jnp oracle — the core build-time correctness signal.

The Pallas INT8 GEMM must match ``dot_general`` bit-for-bit (integer
arithmetic is exact), across shapes, tilings and value ranges; hypothesis
sweeps the space.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ozaki, ref  # noqa: E402

DIMS = st.sampled_from([8, 16, 24, 32, 64, 96, 128])


def rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape, dtype=np.int8))


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_int8_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand_i8(rng, (m, k)), rand_i8(rng, (k, n))
    got = ozaki.int8_gemm(a, b)
    want = ref.int8_gemm_ref(a, b)
    assert got.dtype == jnp.int32
    assert bool(jnp.all(got == want))


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (64, 64, 64),
                                    (32, 64, 16)])
def test_int8_gemm_tilings_agree(blocks):
    """Every legal tiling computes the identical integer result."""
    rng = np.random.default_rng(7)
    a, b = rand_i8(rng, (64, 64)), rand_i8(rng, (64, 64))
    bm, bk, bn = blocks
    got = ozaki.int8_gemm(a, b, bm=bm, bk=bk, bn=bn)
    want = ref.int8_gemm_ref(a, b)
    assert bool(jnp.all(got == want))


def test_int8_gemm_rejects_bad_blocks():
    rng = np.random.default_rng(0)
    a, b = rand_i8(rng, (64, 64)), rand_i8(rng, (64, 64))
    with pytest.raises(AssertionError):
        ozaki.int8_gemm(a, b, bm=48, bk=64, bn=64)


def test_int8_gemm_extreme_values_no_overflow():
    """K * 127^2 accumulation stays exact in INT32."""
    k = 512
    a = jnp.full((8, k), 127, jnp.int8)
    b = jnp.full((k, 8), 127, jnp.int8)
    got = ozaki.int8_gemm(a, b, bm=8, bn=8, bk=k)
    assert bool(jnp.all(got == k * 127 * 127))
    b2 = jnp.full((k, 8), -127, jnp.int8)
    got2 = ozaki.int8_gemm(a, b2, bm=8, bn=8, bk=k)
    assert bool(jnp.all(got2 == -k * 127 * 127))


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, splits=st.integers(2, 9), seed=st.integers(0, 2**31 - 1))
def test_split_kernel_matches_ref(m, k, splits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-0.999, 0.999, (m, k)))
    got = ozaki.split_kernel(x, splits)
    want = ref.split_ref(x, splits)
    assert got.dtype == jnp.int8
    assert bool(jnp.all(got == want))


@settings(max_examples=20, deadline=None)
@given(splits=st.integers(2, 9), seed=st.integers(0, 2**31 - 1))
def test_split_slices_bounded(splits, seed):
    """|q_k| <= 127 always — no int8 saturation (SLICE_BITS = 7)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, (16, 16)) * 0.99999999)
    sl = ref.split_ref(x, splits)
    assert int(jnp.max(jnp.abs(sl.astype(jnp.int32)))) <= 127


@settings(max_examples=20, deadline=None)
@given(splits=st.integers(2, 9), seed=st.integers(0, 2**31 - 1))
def test_split_reconstruction_residual_bound(splits, seed):
    """Residual after s slices is < 2^(-7s) (exact truncation chain)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-0.999, 0.999, (32, 32)))
    rec = ref.reconstruct_ref(ref.split_ref(x, splits))
    # The mathematical residual is < 2^(-7s); evaluating the weighted sum
    # in FP64 adds up to `splits` rounding errors of <= eps/2 each.
    bound = 2.0 ** (-ozaki.SLICE_BITS * splits) + splits * 2.0 ** -53
    assert float(jnp.max(jnp.abs(rec - x))) < bound


def test_split_zero_and_exact_values():
    """Dyadic values reconstruct exactly — this is what forced the model
    to use ldexp rather than XLA's inexact exp2 (see kernels/ref.py)."""
    x = jnp.asarray([[0.0, 0.5, -0.5, 2.0 ** -7, -(2.0 ** -14), 0.75]])
    sl = ref.split_ref(x, 4)
    rec = ref.reconstruct_ref(sl)
    assert float(jnp.max(jnp.abs(rec - x))) == 0.0


def test_vmem_estimate_monotone():
    assert ozaki.vmem_bytes(128, 128, 128) < ozaki.vmem_bytes(256, 256, 256)
    # documented §Perf bound: default MuST bucket fits in 16 MiB
    assert ozaki.vmem_bytes(256, 64, 256) <= 16 * 2 ** 20
