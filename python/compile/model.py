"""Layer-2 JAX model: the ``fp64_int8_s`` DGEMM emulation graph.

This is the Ozaki scheme on an integer matrix-multiplication unit
(Ootomo et al. 2024; Uchino et al. 2025), as used by the paper:

1. scale rows of A (columns of B) by powers of two so entries are < 1;
2. slice every entry into ``s`` signed 7-bit integers (exact);
3. run ONE fused INT8 GEMM over all slice pairs — the Layer-1 Pallas
   kernel — with INT32 accumulation (exact for K < 133k);
4. accumulate the slice-pair products in FP64 with weights
   ``2^{-7(k+l+2)}``, keeping the ``k+l < s`` triangle (ozIMMU_H
   economisation), and undo the scaling.

The whole graph (split + kernel + accumulate) lowers into a single HLO
module so the Rust runtime feeds plain FP64 matrices and receives FP64
results — no host round-trips between stages.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ozaki
from .kernels.ozaki import SLICE_BITS

jax.config.update("jax_enable_x64", True)


def _scale_rows(a):
    """Rowwise power-of-two scaling; see kernels.ref.scale_rows.

    Scaling uses ``ldexp`` (exact exponent manipulation).  ``jnp.exp2``
    must NOT be used here: XLA lowers it to ``exp(x*ln2)`` whose result
    can be one ulp off a true power of two, which would break the
    error-free-transformation property of the Ozaki splitting.
    """
    amax = jnp.max(jnp.abs(a), axis=1, keepdims=True)
    amax = jnp.where(amax == 0, 1.0, amax)
    _, e = jnp.frexp(amax)  # amax = mant * 2**e, mant in [0.5, 1)
    return jnp.ldexp(a, -e), e


def _split(x, splits: int):
    """7-bit truncate-and-rescale slicing, fused into the model graph.

    Identical math to the standalone L1 split kernel; inlined here so XLA
    fuses it with the scaling and the weight application.
    """
    slices = []
    r = x
    for _ in range(splits):
        q = jnp.trunc(r * (2.0 ** SLICE_BITS))
        slices.append(q.astype(jnp.int8))
        r = r * (2.0 ** SLICE_BITS) - q
    return jnp.stack(slices)


def ozaki_dgemm(a, b, splits: int, tile: str = "cpu"):
    """Emulated FP64 GEMM: ``C ≈ A @ B`` computed on INT8 units.

    a: (M, K) f64, b: (K, N) f64 → (M, N) f64.

    ``tile`` selects the L1 kernel's BlockSpec profile (§Perf):

    * ``"cpu"`` — one grid cell covering the whole fused GEMM.  Under
      ``interpret=True`` every grid cell is a scan iteration with
      dynamic-slice traffic, which dominated the measured runtime
      (~40x over the raw int8-dot floor at 256³); CPU has no VMEM
      constraint, so one cell is strictly better there.
    * ``"tpu"`` — (M, N, K) tiles, grid (s, s, 1): each cell's working
      set (bm·bk + bk·bn int8 + 2·4·bm·bn int32) stays inside a 16 MiB
      VMEM budget for the shipped shapes.  This is the layout a real
      MXU build would use; on the CPU testbed it is compile-only
      validated + used for the VMEM/occupancy estimates in DESIGN.md.
    """
    m, k = a.shape
    _, n = b.shape
    a_scaled, ea = _scale_rows(a)
    b_scaled, eb = _scale_rows(b.T)  # column scaling of B
    sa = _split(a_scaled, splits)  # (s, M, K) int8
    sb = _split(b_scaled, splits)  # (s, N, K) int8

    # Per-diagonal packing (§Perf): the retained slice pairs share the
    # weight 2^{-7(d+2)} along each anti-diagonal d = k+l, so pack the
    # pairs of one diagonal into a single INT8 GEMM with contraction
    # K·(d+1):
    #
    #   D_d = [A_0 | A_1 | ... | A_d] @ [B_d; B_{d-1}; ...; B_0]
    #
    # This performs exactly the s(s+1)/2 products of the ozIMMU_H
    # economisation (vs s² for the all-pairs layout) and shrinks the
    # FP64 accumulation from s²·M·N to s·M·N values.  INT32 stays exact:
    # (d+1)·K·127² < 2³¹ for K·(d+1) < 133k.
    c = jnp.zeros((m, n), jnp.float64)
    for d in range(splits):
        a_cat = jnp.concatenate([sa[kk] for kk in range(d + 1)], axis=1)
        b_cat = jnp.concatenate(
            [sb[d - kk].T for kk in range(d + 1)], axis=0
        )  # (K*(d+1), N)
        kd = k * (d + 1)
        if tile == "cpu":
            bm, bn, bk = m, n, kd
        elif tile == "tpu":
            bm, bn, bk = m, n, min(k, kd)
        else:
            raise ValueError(f"unknown tile profile {tile!r}")
        dd = ozaki.int8_gemm(a_cat, b_cat, bm=bm, bn=bn, bk=bk)
        w = jnp.ldexp(jnp.float64(1.0), -SLICE_BITS * (d + 2))
        c = c + dd.astype(jnp.float64) * w
    return jnp.ldexp(c, ea + eb.T)  # exact pow2 unscaling


def native_dgemm(a, b):
    """The paper's ``dgemm`` compute mode: native FP64 dot."""
    return jnp.matmul(a, b)


def make_entry(kind: str, splits: int | None, tile: str = "cpu"):
    """Build the AOT entry point for one artifact.

    All entries take (A, B) FP64 and return a 1-tuple (C,) — the Rust
    runtime unwraps with ``to_tuple1``.
    """
    if kind == "dgemm":
        return lambda a, b: (native_dgemm(a, b),)
    if kind == "ozdg":
        assert splits is not None and splits >= 2
        return lambda a, b: (ozaki_dgemm(a, b, splits, tile=tile),)
    raise ValueError(f"unknown artifact kind {kind!r}")
