"""Pure-jnp reference oracles for the L1 kernels and the L2 emulation.

Everything here is written with plain ``jnp`` ops (no Pallas) and is the
correctness anchor for pytest: the Pallas kernel and the full AOT'd model
must match these bit-for-bit (integer paths) or to tight FP64 tolerances
(emulation paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ozaki import SLICE_BITS


def int8_gemm_ref(a, b):
    """Reference INT8→INT32 GEMM: plain dot_general, no tiling."""
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def scale_rows(a):
    """Per-row power-of-two scaling so every entry has magnitude < 1.

    Returns ``(scaled, e)`` with ``a == scaled * 2**e`` rowwise and
    ``|scaled| < 1``.  Zero rows get e = 0.
    """
    amax = jnp.max(jnp.abs(a), axis=1, keepdims=True)
    amax = jnp.where(amax == 0, 1.0, amax)
    _, e = jnp.frexp(amax)  # amax = mant * 2**e with mant in [0.5, 1)
    # ldexp, not exp2: XLA's exp2 is exp(x*ln2) and can be 1 ulp off a
    # true power of two, which would break error-free splitting.
    return jnp.ldexp(a, -e), e


def split_ref(x, splits: int):
    """Reference 7-bit slicer for pre-scaled input (|x| < 1).

    Returns (splits, ...) int8 such that
    ``x ≈ sum_k slices[k] * 2**(-SLICE_BITS*(k+1))`` with residual
    ``< 2**(-SLICE_BITS*splits)``.  The arithmetic is exact in FP64: the
    scaling is by powers of two and the subtraction is of the truncated
    integer part.
    """
    slices = []
    r = x
    for _ in range(splits):
        q = jnp.trunc(r * (2.0 ** SLICE_BITS))
        slices.append(q.astype(jnp.int8))
        r = r * (2.0 ** SLICE_BITS) - q
    return jnp.stack(slices)


def reconstruct_ref(slices):
    """Inverse of :func:`split_ref` up to the dropped residual."""
    s = slices.shape[0]
    w = jnp.ldexp(jnp.float64(1.0), -SLICE_BITS * (jnp.arange(s) + 1))
    return jnp.einsum("k...,k->...", slices.astype(jnp.float64), w)


def ozaki_dgemm_ref(a, b, splits: int):
    """Reference fp64_int8_s DGEMM: identical math to the L2 model
    (per-diagonal packed products — see model.ozaki_dgemm) but with an
    un-tiled dot_general in place of the Pallas kernel."""
    m, _k = a.shape
    _, n = b.shape
    sa_scaled, ea = scale_rows(a)
    sb_scaled, eb = scale_rows(b.T)
    sa = split_ref(sa_scaled, splits)  # (s, M, K)
    sb = split_ref(sb_scaled, splits)  # (s, N, K)
    c = jnp.zeros((m, n), jnp.float64)
    for d in range(splits):
        a_cat = jnp.concatenate([sa[kk] for kk in range(d + 1)], axis=1)
        b_cat = jnp.concatenate([sb[d - kk].T for kk in range(d + 1)], axis=0)
        dd = int8_gemm_ref(a_cat, b_cat)
        w = jnp.ldexp(jnp.float64(1.0), -SLICE_BITS * (d + 2))
        c = c + dd.astype(jnp.float64) * w
    return jnp.ldexp(c, ea + eb.T)


def dgemm_ref(a, b):
    """Native FP64 GEMM (the paper's `dgemm` compute mode)."""
    return a @ b


def zgemm_via_dgemm_ref(ar, ai, br, bi, splits: int | None):
    """ZGEMM decomposed into four real GEMMs, each optionally emulated.

    This mirrors how the Rust coordinator lowers complex GEMMs; ozIMMU
    likewise splits real/imaginary parts.
    """
    g = (lambda x, y: ozaki_dgemm_ref(x, y, splits)) if splits else dgemm_ref
    cre = g(ar, br) - g(ai, bi)
    cim = g(ar, bi) + g(ai, br)
    return cre, cim
