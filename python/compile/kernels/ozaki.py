"""Layer-1 Pallas kernels for the Ozaki-scheme INT8 GEMM emulation.

The compute hot-spot of ``fp64_int8_s`` DGEMM emulation is a set of
packed INT8 matrix multiplications with INT32 accumulation — one per
anti-diagonal ``d`` of the slice-pair grid (the ``s(s+1)/2`` retained
products of the ozIMMU_H economisation):

    D_d = [A_0 | ... | A_d] @ [B_d; ...; B_0]       contraction K*(d+1)

On real IMMU hardware (NVIDIA integer tensor cores, TPU MXU int8 mode) this
maps onto the native 8-bit multiply / 32-bit accumulate path.  Here the
kernel is written in Pallas and lowered with ``interpret=True`` so the same
HLO runs on the CPU PJRT backend (see DESIGN.md §Hardware-Adaptation: real
TPU lowering would emit a Mosaic custom-call the CPU plugin cannot execute).

Two kernels live here:

* :func:`int8_gemm` — tiled INT8 GEMM with an INT32 scratch accumulator.
* :func:`split_kernel` — the 7-bit truncate-and-rescale slicer, exposed as a
  standalone Pallas kernel for benchmarking; the L2 model normally fuses the
  equivalent jnp computation into the same HLO module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bits carried per INT8 slice.  7 (not 8) so that truncation of a scaled
# mantissa |r| < 1 yields |q| = |trunc(r * 2^7)| <= 127, which fits int8
# without saturation, and so K*127^2 stays far below the INT32 accumulator
# limit (exact for K < 133_000).
SLICE_BITS = 7


def _gemm_body(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid cell of the INT8 GEMM: one (bm, bk) x (bk, bn) MAC step.

    Grid is (M/bm, N/bn, K/bk); the K axis is innermost so the INT32
    accumulator lives in scratch (VMEM on a real TPU) across K steps.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def int8_gemm(a, b, *, bm: int | None = None, bn: int | None = None,
              bk: int | None = None):
    """INT8 matrix multiply with exact INT32 accumulation.

    ``a``: (M, K) int8, ``b``: (K, N) int8 → (M, N) int32.

    Block sizes must divide the corresponding dimensions; callers pick them
    so the grid stays small under ``interpret=True`` (every grid cell is a
    scan iteration on CPU).  Defaults take the whole axis when it is modest
    and otherwise the largest power-of-two tile that divides it.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"blocks ({bm},{bk},{bn}) must divide shape ({m},{k},{n})")
    nm, nn, nk = m // bm, n // bn, k // bk
    return pl.pallas_call(
        functools.partial(_gemm_body, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[
            pl.MemoryRef(jax.core.ShapedArray((bm, bn), jnp.int32),
                         pl.MemorySpace.ANY)
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
        name="ozaki_int8_gemm",
    )(a, b)


def _pick_block(dim: int, cap: int = 512) -> int:
    """Largest divisor of ``dim`` that is <= cap and keeps tiles chunky."""
    if dim <= cap:
        return dim
    best = 1
    block = cap
    while block >= 1:
        if dim % block == 0:
            best = block
            break
        block //= 2
    return max(best, 1)


def vmem_bytes(bm: int, bk: int, bn: int) -> int:
    """Estimated VMEM footprint of one grid cell (DESIGN.md §Perf).

    int8 A-tile + int8 B-tile + int32 accumulator + int32 output tile.
    """
    return bm * bk + bk * bn + 2 * 4 * bm * bn


def _split_body(x_ref, o_ref, *, splits: int):
    """Slice a pre-scaled block (|x| < 1) into ``splits`` 7-bit integers."""
    r = x_ref[...]
    for s in range(splits):
        q = jnp.trunc(r * (2.0 ** SLICE_BITS))
        o_ref[s, ...] = q.astype(jnp.int8)
        # Exact: power-of-two scaling then subtraction of the truncated
        # integer part (Sterbenz) leaves |r| < 1 for the next round.
        r = r * (2.0 ** SLICE_BITS) - q


def split_kernel(x, splits: int, *, block: int | None = None):
    """Standalone Pallas slicer: (M, K) f64 with |x| < 1 → (splits, M, K) i8.

    The L2 model fuses an equivalent jnp loop; this kernel exists so the
    split stage can be benchmarked and tested in isolation at L1.
    """
    m, k = x.shape
    bm = block or _pick_block(m)
    return pl.pallas_call(
        functools.partial(_split_body, splits=splits),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((splits, bm, k), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((splits, m, k), jnp.int8),
        interpret=True,
        name="ozaki_split",
    )(x)
