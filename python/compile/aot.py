"""AOT driver: lower the L2 model to HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are shape-specialised.  The shape set covers the buckets the
Rust runtime pads to (DESIGN.md §Shape/bucket policy): the K=64 family
used by MuST-mini's blocked LU trailing updates plus square benchmark
shapes.  ``artifacts/manifest.txt`` lists every module as

    kind splits M K N filename

with ``splits = 0`` for the native-FP64 ``dgemm`` mode.

The L1 kernel tiling defaults to the CPU execution profile (one grid
cell — see model.ozaki_dgemm's docstring and EXPERIMENTS.md §Perf);
pass ``--tile tpu`` to emit the MXU-shaped tiled variant instead
(compile-only on this testbed).

Usage:  python -m compile.aot --out-dir ../artifacts [--jobs N] [--quick] [--tile cpu|tpu]
"""

from __future__ import annotations

import argparse
import concurrent.futures as cf
import os
import sys
import time

# MuST-mini blocked-LU trailing-update shapes (dim 256, NB 64), padded
# to buckets {64, 128, 256}.
MUST_SHAPES = [
    (m, 64, n) for m in (64, 128, 256) for n in (64, 128, 256)
]
# Square shapes for the §4 DGEMM benchmark (E3).  2048 is modelled, not
# compiled — interpret-mode emulation at 2048^3 x s^2 is out of testbed
# budget; perfmodel extrapolates from these.
BENCH_SHAPES = [(128, 128, 128), (256, 256, 256), (512, 512, 512)]

SPLITS = list(range(3, 10))  # fp64_int8_3 .. fp64_int8_9 (Table 1)


def artifact_name(kind: str, splits: int, m: int, k: int, n: int) -> str:
    if kind == "dgemm":
        return f"dgemm_{m}x{k}x{n}.hlo.txt"
    return f"ozdg_s{splits}_{m}x{k}x{n}.hlo.txt"


def lower_one(job):
    """Lower one (kind, splits, m, k, n[, tile]) to HLO text.  Runs in a
    worker process: jax + the model are imported lazily so processes
    stay cheap."""
    kind, splits, m, k, n, out_dir = job[:6]
    tile = job[6] if len(job) > 6 else "cpu"
    import jax
    import jax.numpy as jnp

    jax.config.update("jax_enable_x64", True)
    from jax._src.lib import xla_client as xc

    from . import model

    fn = model.make_entry(kind, splits, tile=tile)
    a = jax.ShapeDtypeStruct((m, k), jnp.float64)
    b = jax.ShapeDtypeStruct((k, n), jnp.float64)
    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(a, b)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    name = artifact_name(kind, splits, m, k, n)
    path = os.path.join(out_dir, name)
    with open(path + ".tmp", "w") as f:
        f.write(text)
    os.replace(path + ".tmp", path)
    return name, len(text), time.perf_counter() - t0


def build_jobs(out_dir: str, quick: bool, tile: str = "cpu"):
    shapes = sorted(set(MUST_SHAPES + BENCH_SHAPES))
    splits = SPLITS if not quick else [3, 6]
    jobs = []
    for (m, k, n) in shapes:
        for kind, ss in [("dgemm", [0])] + [("ozdg", splits)]:
            for s in ss:
                name = artifact_name(kind, s, m, k, n)
                if not os.path.exists(os.path.join(out_dir, name)):
                    jobs.append((kind, s, m, k, n, out_dir, tile))
    return shapes, splits, jobs


def write_manifest(out_dir: str, quick: bool):
    shapes = sorted(set(MUST_SHAPES + BENCH_SHAPES))
    splits = SPLITS if not quick else [3, 6]
    lines = []
    for (m, k, n) in shapes:
        lines.append(f"dgemm 0 {m} {k} {n} {artifact_name('dgemm', 0, m, k, n)}")
        for s in splits:
            lines.append(f"ozdg {s} {m} {k} {n} {artifact_name('ozdg', s, m, k, n)}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# kind splits M K N filename\n")
        f.write("\n".join(lines) + "\n")
    return len(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--jobs", type=int, default=os.cpu_count() or 4)
    ap.add_argument("--quick", action="store_true",
                    help="only splits {3,6} — for CI smoke runs")
    ap.add_argument("--tile", choices=["cpu", "tpu"], default="cpu",
                    help="L1 kernel BlockSpec profile (see §Perf)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    _, _, jobs = build_jobs(args.out_dir, args.quick, args.tile)
    t0 = time.perf_counter()
    if jobs:
        print(f"lowering {len(jobs)} modules with {args.jobs} workers ...")
        with cf.ProcessPoolExecutor(max_workers=args.jobs) as ex:
            for name, nbytes, dt in ex.map(lower_one, jobs):
                print(f"  {name:34s} {nbytes/1024:7.1f} KiB  {dt:5.1f}s")
    else:
        print("all artifacts up to date")
    n = write_manifest(args.out_dir, args.quick)
    print(f"manifest: {n} modules; total {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
