"""Build-time compile path: L2 JAX model, L1 kernels, AOT driver."""
