//! Automatic-offload walkthrough (the SCILIB-Accel story, paper §2.1):
//! a synthetic BLAS-heavy workload issues GEMMs of mixed sizes from
//! several call sites; the coordinator routes each one (host for small,
//! device for large), tracks per-call-site statistics PEAK-style, and
//! prices the data movement under all three UMA strategies.
//!
//! Run with `cargo run --release --example offload_trace`.

use ozaccel::coordinator::{DataMoveStrategy, DispatchConfig, Dispatcher};
use ozaccel::linalg::Mat;
use ozaccel::ozaki::ComputeMode;
use ozaccel::testing::Rng;

/// A fake application phase: repeated small updates (stay on host).
fn small_updates(d: &Dispatcher, rng: &mut Rng) -> ozaccel::Result<()> {
    let a = Mat::from_fn(24, 24, |_, _| rng.normal());
    let b = Mat::from_fn(24, 24, |_, _| rng.normal());
    for _ in 0..20 {
        d.dgemm(&a, &b)?; // call site A — below the offload threshold
    }
    Ok(())
}

/// A fake solver phase: large products reusing the same operands
/// (offloaded; first-touch migration pays once).
fn solver_phase(d: &Dispatcher, rng: &mut Rng) -> ozaccel::Result<()> {
    let a = Mat::from_fn(256, 256, |_, _| rng.normal());
    let b = Mat::from_fn(256, 256, |_, _| rng.normal());
    for _ in 0..10 {
        let c = d.dgemm(&a, &b)?; // call site B — offloaded
        d.cpu_touch(&c); // application reads the result on the CPU
    }
    Ok(())
}

fn main() -> ozaccel::Result<()> {
    ozaccel::logging::init();
    for strategy in [
        DataMoveStrategy::CopyAlways,
        DataMoveStrategy::UnifiedAccess,
        DataMoveStrategy::FirstTouchMigrate,
    ] {
        let cfg = DispatchConfig {
            mode: ComputeMode::Int8 { splits: 6 },
            strategy,
            ..DispatchConfig::default()
        };
        let d = Dispatcher::new(cfg)?;
        let mut rng = Rng::new(1);
        small_updates(&d, &mut rng)?;
        solver_phase(&d, &mut rng)?;
        println!("{}", d.report().render());
    }
    println!("note how only the large-GEMM call site is offloaded, and how");
    println!("first_touch moves the fewest bytes on the reuse-heavy phase —");
    println!("the UMA advantage that makes automatic offload viable (§2.1).");
    Ok(())
}
