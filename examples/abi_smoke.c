/*
 * abi_smoke — drop-in BLAS interception smoke caller.
 *
 * A plain C program that calls the Fortran BLAS ABI (dgemm_/zgemm_)
 * exactly as an unmodified application would: column-major buffers,
 * padded leading dimensions, every transpose combination, alpha/beta
 * classes including beta == 0 over NaN-poisoned output.  It carries
 * its own textbook oracle (same pinned evaluation order as ozaccel's
 * fixed FP64 path) and bitwise-compares every call, printing a
 * deterministic digest per case.
 *
 * Two ways to run it (see the CI `abi` job):
 *   1. linked against examples/naive_blas.c — the baseline;
 *   2. the same binary under LD_PRELOAD=libozaccel_blas.so — the
 *      drop-in interception.
 * In fixed FP64 mode both stdouts must be byte-identical, and both
 * must match the pinned examples/abi_smoke.expected.
 *
 * Compile with -ffp-contract=off: the oracle must not be fused into
 * FMA forms the interposed library does not use.
 */

#include <math.h>
#include <pthread.h>
#include <stdio.h>
#include <string.h>

typedef struct {
    double re, im;
} z16;

extern void dgemm_(const char *transa, const char *transb, const int *m, const int *n,
                   const int *k, const double *alpha, const double *a, const int *lda,
                   const double *b, const int *ldb, const double *beta, double *c,
                   const int *ldc);
extern void zgemm_(const char *transa, const char *transb, const int *m, const int *n,
                   const int *k, const z16 *alpha, const z16 *a, const int *lda, const z16 *b,
                   const int *ldb, const z16 *beta, z16 *c, const int *ldc);

static int checks = 0;
static int failures = 0;

/* ----------------------------------------------------------------- */
/* Deterministic input generator (64-bit LCG, top 53 bits).           */
/* ----------------------------------------------------------------- */

static unsigned long long lcg_state = 42ULL;

static double next_rand(void)
{
    lcg_state = lcg_state * 6364136223846793005ULL + 1442695040888963407ULL;
    return ((double)(lcg_state >> 11)) / 9007199254740992.0 - 0.5;
}

static void fill(double *buf, int len)
{
    int i;
    for (i = 0; i < len; i++)
        buf[i] = next_rand();
}

/* ----------------------------------------------------------------- */
/* Internal oracles — same pinned arithmetic as examples/naive_blas.c */
/* ----------------------------------------------------------------- */

static int is_trans(char t)
{
    return t == 'T' || t == 't' || t == 'C' || t == 'c';
}

static int is_conj(char t)
{
    return t == 'C' || t == 'c';
}

static void oracle_dgemm(char ta, char tb, int m, int n, int k, double alpha, const double *a,
                         int lda, const double *b, int ldb, double beta, double *c, int ldc)
{
    int i, j, p;
    if (m == 0 || n == 0)
        return;
    if (alpha == 0.0 || k == 0) {
        for (j = 0; j < n; j++)
            for (i = 0; i < m; i++)
                c[i + j * ldc] = (beta == 0.0) ? 0.0 : beta * c[i + j * ldc];
        return;
    }
    for (j = 0; j < n; j++) {
        for (i = 0; i < m; i++) {
            double acc = 0.0;
            for (p = 0; p < k; p++) {
                double av = is_trans(ta) ? a[p + i * lda] : a[i + p * lda];
                double bv = is_trans(tb) ? b[j + p * ldb] : b[p + j * ldb];
                acc += av * bv;
            }
            c[i + j * ldc] = (beta == 0.0) ? alpha * acc : alpha * acc + beta * c[i + j * ldc];
        }
    }
}

static z16 zmul(z16 x, z16 y)
{
    z16 r;
    r.re = x.re * y.re - x.im * y.im;
    r.im = x.re * y.im + x.im * y.re;
    return r;
}

static void oracle_zgemm(char ta, char tb, int m, int n, int k, z16 alpha, const z16 *a,
                         int lda, const z16 *b, int ldb, z16 beta, z16 *c, int ldc)
{
    int beta_zero = beta.re == 0.0 && beta.im == 0.0;
    int i, j, p;
    if (m == 0 || n == 0)
        return;
    if ((alpha.re == 0.0 && alpha.im == 0.0) || k == 0) {
        for (j = 0; j < n; j++) {
            for (i = 0; i < m; i++) {
                z16 *cv = &c[i + j * ldc];
                if (beta_zero) {
                    cv->re = 0.0;
                    cv->im = 0.0;
                } else {
                    *cv = zmul(beta, *cv);
                }
            }
        }
        return;
    }
    for (j = 0; j < n; j++) {
        for (i = 0; i < m; i++) {
            double rr = 0.0, ii = 0.0, ri = 0.0, ir = 0.0;
            z16 prod, upd;
            for (p = 0; p < k; p++) {
                z16 av = is_trans(ta) ? a[p + i * lda] : a[i + p * lda];
                z16 bv = is_trans(tb) ? b[j + p * ldb] : b[p + j * ldb];
                if (is_conj(ta))
                    av.im = -av.im;
                if (is_conj(tb))
                    bv.im = -bv.im;
                rr += av.re * bv.re;
                ii += av.im * bv.im;
                ri += av.re * bv.im;
                ir += av.im * bv.re;
            }
            prod.re = rr - ii;
            prod.im = ri + ir;
            upd = zmul(alpha, prod);
            if (!beta_zero) {
                z16 bc = zmul(beta, c[i + j * ldc]);
                upd.re = upd.re + bc.re;
                upd.im = upd.im + bc.im;
            }
            c[i + j * ldc] = upd;
        }
    }
}

/* ----------------------------------------------------------------- */
/* DGEMM sweep                                                        */
/* ----------------------------------------------------------------- */

#define DM 5
#define DN 4
#define DK 3
#define DLDA 8
#define DLDB 7
#define DLDC 6

static void run_dgemm_case(char ta, char tb, double alpha, double beta)
{
    double a[DLDA * 8], b[DLDB * 8], c[DLDC * DN], ref[DLDC * DN];
    int m = DM, n = DN, k = DK, lda = DLDA, ldb = DLDB, ldc = DLDC;
    int i, j;
    double digest = 0.0;

    fill(a, DLDA * 8);
    fill(b, DLDB * 8);
    if (beta == 0.0) {
        /* beta == 0 must overwrite, never read: poison the output. */
        for (i = 0; i < DLDC * DN; i++)
            c[i] = NAN;
    } else {
        fill(c, DLDC * DN);
    }
    memcpy(ref, c, sizeof c);

    dgemm_(&ta, &tb, &m, &n, &k, &alpha, a, &lda, b, &ldb, &beta, c, &ldc);
    oracle_dgemm(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, ref, ldc);

    checks++;
    if (memcmp(c, ref, sizeof c) != 0) {
        failures++;
        printf("MISMATCH dgemm %c%c alpha=%.17g beta=%.17g\n", ta, tb, alpha, beta);
    }
    for (j = 0; j < n; j++)
        for (i = 0; i < m; i++)
            digest += c[i + j * ldc];
    printf("dgemm %c%c alpha=%.3g beta=%.3g digest=%.17g\n", ta, tb, alpha, beta, digest);
}

/* ----------------------------------------------------------------- */
/* ZGEMM sweep                                                        */
/* ----------------------------------------------------------------- */

#define ZM 4
#define ZN 3
#define ZK 5
#define ZLDA 7
#define ZLDB 6
#define ZLDC 5

static void run_zgemm_case(char ta, char tb, z16 alpha, z16 beta)
{
    z16 a[ZLDA * 8], b[ZLDB * 8], c[ZLDC * ZN], ref[ZLDC * ZN];
    int m = ZM, n = ZN, k = ZK, lda = ZLDA, ldb = ZLDB, ldc = ZLDC;
    int beta_zero = beta.re == 0.0 && beta.im == 0.0;
    int i, j;
    double digest = 0.0;

    fill((double *)a, 2 * ZLDA * 8);
    fill((double *)b, 2 * ZLDB * 8);
    if (beta_zero) {
        for (i = 0; i < ZLDC * ZN; i++) {
            c[i].re = NAN;
            c[i].im = NAN;
        }
    } else {
        fill((double *)c, 2 * ZLDC * ZN);
    }
    memcpy(ref, c, sizeof c);

    zgemm_(&ta, &tb, &m, &n, &k, &alpha, a, &lda, b, &ldb, &beta, c, &ldc);
    oracle_zgemm(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, ref, ldc);

    checks++;
    if (memcmp(c, ref, sizeof c) != 0) {
        failures++;
        printf("MISMATCH zgemm %c%c alpha=(%.17g,%.17g) beta=(%.17g,%.17g)\n", ta, tb,
               alpha.re, alpha.im, beta.re, beta.im);
    }
    for (j = 0; j < n; j++) {
        for (i = 0; i < m; i++) {
            digest += c[i + j * ldc].re;
            digest += c[i + j * ldc].im;
        }
    }
    printf("zgemm %c%c alpha=(%.3g,%.3g) beta=(%.3g,%.3g) digest=%.17g\n", ta, tb, alpha.re,
           alpha.im, beta.re, beta.im, digest);
}

/* ----------------------------------------------------------------- */
/* Concurrent calls (pthreads) through the interposed symbol          */
/* ----------------------------------------------------------------- */

#define TM 16
#define TN 13
#define TK 11
#define TLDA 17
#define TLDB 12
#define TLDC 16
#define THREADS 4
#define ITERS 8

typedef struct {
    const double *a, *b, *ref;
    int fails;
} thread_arg;

static void *thread_body(void *argp)
{
    thread_arg *arg = (thread_arg *)argp;
    char ta = 'N', tb = 'N';
    int m = TM, n = TN, k = TK, lda = TLDA, ldb = TLDB, ldc = TLDC;
    double alpha = 1.0, beta = 0.0;
    int it, i;

    for (it = 0; it < ITERS; it++) {
        double c[TLDC * TN];
        for (i = 0; i < TLDC * TN; i++)
            c[i] = NAN;
        dgemm_(&ta, &tb, &m, &n, &k, &alpha, arg->a, &lda, arg->b, &ldb, &beta, c, &ldc);
        if (memcmp(c, arg->ref, sizeof c) != 0)
            arg->fails++;
    }
    return NULL;
}

static void run_threads(void)
{
    static double a[TLDA * TK], b[TLDB * TN], ref[TLDC * TN];
    pthread_t threads[THREADS];
    thread_arg args[THREADS];
    int t, i, total_fails = 0;

    fill(a, TLDA * TK);
    fill(b, TLDB * TN);
    for (i = 0; i < TLDC * TN; i++)
        ref[i] = NAN;
    oracle_dgemm('N', 'N', TM, TN, TK, 1.0, a, TLDA, b, TLDB, 0.0, ref, TLDC);

    for (t = 0; t < THREADS; t++) {
        args[t].a = a;
        args[t].b = b;
        args[t].ref = ref;
        args[t].fails = 0;
        pthread_create(&threads[t], NULL, thread_body, &args[t]);
    }
    for (t = 0; t < THREADS; t++) {
        pthread_join(threads[t], NULL);
        total_fails += args[t].fails;
    }
    checks += THREADS * ITERS;
    failures += total_fails;
    printf("threads=%d iters=%d fails=%d\n", THREADS, ITERS, total_fails);
}

/* ----------------------------------------------------------------- */

int main(void)
{
    static const char trans[3] = {'N', 'T', 'C'};
    static const double alphas[4] = {0.0, 1.0, -1.0, 0.7};
    static const double betas[4] = {0.0, 1.0, -1.0, 0.5};
    int ti, tj, ai, bi, s;

    for (ti = 0; ti < 3; ti++)
        for (tj = 0; tj < 3; tj++)
            for (ai = 0; ai < 4; ai++)
                for (bi = 0; bi < 4; bi++)
                    run_dgemm_case(trans[ti], trans[tj], alphas[ai], betas[bi]);

    {
        static const z16 zalphas[4] = {{0.0, 0.0}, {1.0, 0.0}, {-1.0, 0.0}, {0.7, -0.3}};
        static const z16 zbetas[4] = {{0.0, 0.0}, {1.0, 0.0}, {0.0, 1.0}, {0.5, -0.25}};
        for (ti = 0; ti < 3; ti++)
            for (tj = 0; tj < 3; tj++)
                for (s = 0; s < 4; s++)
                    run_zgemm_case(trans[ti], trans[tj], zalphas[s], zbetas[s]);
    }

    run_threads();

    printf("abi_smoke: %s (checks=%d, failures=%d)\n", failures ? "FAIL" : "PASS", checks,
           failures);
    return failures ? 1 : 0;
}
