//! End-to-end driver (DESIGN.md E1/E4): run the MuST-mini `mt-u56-mini`
//! case — a dim-256 KKR multiple-scattering problem — through the full
//! three-layer stack (Rust coordinator → PJRT → AOT'd JAX/Pallas INT8
//! emulation), for the native mode and one INT8 mode, and print the
//! accuracy + offload report.
//!
//! Run with:
//!   cargo run --release --example must_scf            (full case)
//!   cargo run --release --example must_scf -- --quick (tiny case)
//!   OZIMMU_COMPUTE_MODE=fp64_int8_5 cargo run --release --example must_scf

use ozaccel::coordinator::{DispatchConfig, Dispatcher};
use ozaccel::experiments::table1::error_row;
use ozaccel::must::params::{mt_u56_mini, tiny_case};
use ozaccel::must::scf::{ModeSelect, ScfDriver};
use ozaccel::ozaki::ComputeMode;

fn main() -> ozaccel::Result<()> {
    ozaccel::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let case = if quick { tiny_case() } else { mt_u56_mini() };
    let mode = ComputeMode::from_env()?;
    let mode = if mode == ComputeMode::Dgemm {
        ComputeMode::Int8 { splits: 6 }
    } else {
        mode
    };

    let dispatcher = Dispatcher::new(DispatchConfig::default())?;
    println!(
        "case: {} sites, dim {}, {} contour points, resonance at {} Ry",
        case.n_sites,
        case.dim(),
        case.n_contour,
        case.e_res
    );
    println!("PJRT runtime attached: {}\n", dispatcher.has_runtime());

    let driver = ScfDriver::new(case, &dispatcher)?;
    println!("running dgemm reference ...");
    let reference = driver.run(ModeSelect::Fixed(ComputeMode::Dgemm))?;
    println!("running {} ...", mode.name());
    dispatcher.reset_stats();
    let emulated = driver.run(ModeSelect::Fixed(mode))?;

    println!("\niter |   Etot(dgemm)    Etot({})  |  EF(dgemm)  EF(emul) | max_real  max_imag", mode.short_name());
    let row = error_row(&reference, &emulated);
    for (i, ((r, e), c)) in reference
        .iterations
        .iter()
        .zip(&emulated.iterations)
        .zip(&row.cells)
        .enumerate()
    {
        println!(
            "  {}  | {:12.6} {:12.6} | {:9.5} {:9.5} | {:.2e}  {:.2e}",
            i + 1,
            r.etot,
            e.etot,
            r.efermi,
            e.efermi,
            c.max_real,
            c.max_imag
        );
    }

    println!("\n{}", dispatcher.report().render());
    Ok(())
}
