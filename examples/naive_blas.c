/*
 * Reference Fortran-ABI BLAS (dgemm_/zgemm_ only) used as the
 * abi_smoke baseline: the smoke binary links against this shared
 * library, producing textbook results; running the same binary under
 * LD_PRELOAD=libozaccel_blas.so interposes the ozaccel drop-in, and in
 * fixed FP64 mode the two stdout streams must be bit-for-bit
 * identical.
 *
 * The arithmetic deliberately mirrors ozaccel's pinned evaluation
 * order: per-element ascending-p accumulation, the BLAS update written
 * literally as alpha*acc + beta*c (overwrite at beta == 0, never
 * reading C), and the complex product in the 4-real-accumulator
 * decomposition (rr - ii, ri + ir).  Compile with -ffp-contract=off so
 * the compiler cannot fuse these expressions.
 */

typedef struct {
    double re, im;
} z16;

static int is_trans(char t)
{
    return t == 'T' || t == 't' || t == 'C' || t == 'c';
}

static int is_conj(char t)
{
    return t == 'C' || t == 'c';
}

void dgemm_(const char *transa, const char *transb, const int *pm, const int *pn,
            const int *pk, const double *palpha, const double *a, const int *plda,
            const double *b, const int *pldb, const double *pbeta, double *c,
            const int *pldc)
{
    char ta = *transa, tb = *transb;
    int m = *pm, n = *pn, k = *pk, lda = *plda, ldb = *pldb, ldc = *pldc;
    double alpha = *palpha, beta = *pbeta;
    int i, j, p;

    if (m == 0 || n == 0)
        return;
    if (alpha == 0.0 || k == 0) {
        for (j = 0; j < n; j++)
            for (i = 0; i < m; i++)
                c[i + j * ldc] = (beta == 0.0) ? 0.0 : beta * c[i + j * ldc];
        return;
    }
    for (j = 0; j < n; j++) {
        for (i = 0; i < m; i++) {
            double acc = 0.0;
            for (p = 0; p < k; p++) {
                double av = is_trans(ta) ? a[p + i * lda] : a[i + p * lda];
                double bv = is_trans(tb) ? b[j + p * ldb] : b[p + j * ldb];
                acc += av * bv;
            }
            c[i + j * ldc] = (beta == 0.0) ? alpha * acc : alpha * acc + beta * c[i + j * ldc];
        }
    }
}

static z16 zmul(z16 x, z16 y)
{
    z16 r;
    r.re = x.re * y.re - x.im * y.im;
    r.im = x.re * y.im + x.im * y.re;
    return r;
}

void zgemm_(const char *transa, const char *transb, const int *pm, const int *pn,
            const int *pk, const z16 *alpha, const z16 *a, const int *plda, const z16 *b,
            const int *pldb, const z16 *beta, z16 *c, const int *pldc)
{
    char ta = *transa, tb = *transb;
    int m = *pm, n = *pn, k = *pk, lda = *plda, ldb = *pldb, ldc = *pldc;
    int beta_zero = beta->re == 0.0 && beta->im == 0.0;
    int i, j, p;

    if (m == 0 || n == 0)
        return;
    if ((alpha->re == 0.0 && alpha->im == 0.0) || k == 0) {
        for (j = 0; j < n; j++) {
            for (i = 0; i < m; i++) {
                z16 *cv = &c[i + j * ldc];
                if (beta_zero) {
                    cv->re = 0.0;
                    cv->im = 0.0;
                } else {
                    *cv = zmul(*beta, *cv);
                }
            }
        }
        return;
    }
    for (j = 0; j < n; j++) {
        for (i = 0; i < m; i++) {
            double rr = 0.0, ii = 0.0, ri = 0.0, ir = 0.0;
            z16 prod, upd;
            for (p = 0; p < k; p++) {
                z16 av = is_trans(ta) ? a[p + i * lda] : a[i + p * lda];
                z16 bv = is_trans(tb) ? b[j + p * ldb] : b[p + j * ldb];
                if (is_conj(ta))
                    av.im = -av.im;
                if (is_conj(tb))
                    bv.im = -bv.im;
                rr += av.re * bv.re;
                ii += av.im * bv.im;
                ri += av.re * bv.im;
                ir += av.im * bv.re;
            }
            prod.re = rr - ii;
            prod.im = ri + ir;
            upd = zmul(*alpha, prod);
            if (!beta_zero) {
                z16 bc = zmul(*beta, c[i + j * ldc]);
                upd.re = upd.re + bc.re;
                upd.im = upd.im + bc.im;
            }
            c[i + j * ldc] = upd;
        }
    }
}
