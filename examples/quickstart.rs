//! Quickstart: emulate one FP64 GEMM on INT8 units through the offload
//! coordinator, sweep the split count, and print the error-vs-mode
//! table.  Run with `cargo run --release --example quickstart`
//! (after `make artifacts`; falls back to pure-host emulation without
//! them).

use ozaccel::coordinator::{DispatchConfig, Dispatcher};
use ozaccel::linalg::{dgemm, Mat};
use ozaccel::ozaki::ComputeMode;
use ozaccel::testing::{max_rel_err, Rng};

fn main() -> ozaccel::Result<()> {
    ozaccel::logging::init();

    // A 256x256 FP64 GEMM — the typical block size in MuST-mini.
    let n = 256;
    let mut rng = Rng::new(42);
    let a = Mat::from_fn(n, n, |_, _| rng.normal());
    let b = Mat::from_fn(n, n, |_, _| rng.normal());
    let exact = dgemm(&a, &b)?;

    println!("mode        max rel err   (vs native FP64)");
    for splits in 3..=9u32 {
        let cfg = DispatchConfig {
            mode: ComputeMode::Int8 { splits },
            ..DispatchConfig::default()
        };
        let dispatcher = Dispatcher::new(cfg)?;
        let c = dispatcher.dgemm(&a, &b)?;
        println!(
            "fp64_int8_{splits}  {:.3e}    offloaded: {}",
            max_rel_err(c.data(), exact.data()),
            dispatcher.report().offloaded_calls > 0,
        );
    }
    println!("\nEach +1 split buys ~2 decimal digits (2^-7 per slice) until");
    println!("the FP64 floor at s=8 — the paper's Table-1 pattern.");
    Ok(())
}
