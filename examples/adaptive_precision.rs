//! Tunable precision in action (paper §4's proposal, measured rather
//! than assumed): solve the MuST-mini τ-matrix along the energy contour
//! under the *feedback* precision governor — the split count is seeded
//! from the a-priori error bound, then FP64 probes and the measured
//! condition number ramp it per call site: few splits where the KKR
//! matrix is well-conditioned, many near the 0.72 Ry resonance.
//!
//! Run with `cargo run --release --example adaptive_precision`.

use ozaccel::coordinator::{DispatchConfig, Dispatcher};
use ozaccel::must::params::{mt_u56_mini, tiny_case};
use ozaccel::must::scf::{ModeSelect, ScfDriver};
use ozaccel::ozaki::ComputeMode;
use ozaccel::precision::{PrecisionConfig, PrecisionMode};

fn main() -> ozaccel::Result<()> {
    ozaccel::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let mut case = if quick { tiny_case() } else { mt_u56_mini() };
    case.iterations = 1;

    let mut cfg = DispatchConfig::host_only(ComputeMode::Int8 { splits: 18 });
    cfg.precision = PrecisionConfig {
        mode: PrecisionMode::Feedback,
        target: 1e-9,
        ..Default::default()
    };
    let dispatcher = Dispatcher::new(cfg)?;
    let driver = ScfDriver::new(case, &dispatcher)?;
    let run = driver.run(ModeSelect::Governed)?;

    println!("per-energy-point split choice (feedback governor, target 1e-9):\n");
    println!("   Re(z)    Im(z)     kappa(est)   splits");
    for p in &run.iterations[0].points {
        let bar = "#".repeat(p.splits_used as usize);
        println!(
            " {:7.4}  {:7.4}  {:10.2e}   {:2}  {bar}",
            p.z.re, p.z.im, p.kappa, p.splits_used
        );
    }
    let mean: f64 = run.iterations[0]
        .points
        .iter()
        .map(|p| p.splits_used as f64)
        .sum::<f64>()
        / run.iterations[0].points.len() as f64;
    println!(
        "\nmean splits {mean:.2} — vs a fixed policy that must run the max\n\
         everywhere; cost scales with s(s+1)/2 per GEMM (paper §4:\n\
         \"minimizing splits while maintaining accuracy is critical\")."
    );
    // The governor's own per-site view: calibrated error constant,
    // last fed κ, probe count, and the decision trajectory.
    println!("\ngovernor state per call site:");
    for (site, snap) in dispatcher.governor().snapshots() {
        println!(
            "  {site}: splits {:>2}  kappa {:.2e}  calib {:.3}  probes {}  trajectory {:?}",
            snap.splits, snap.kappa, snap.calib, snap.probes, snap.trajectory
        );
    }
    // The PEAK report shows the execution-side footprint per call site:
    // the split trajectory (`splits`) and the probe cost (`probe_ms`).
    println!("\n{}", dispatcher.report().render());
    Ok(())
}
