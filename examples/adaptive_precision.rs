//! Tunable precision in action (paper §4's proposal): solve the
//! MuST-mini τ-matrix along the energy contour with the adaptive
//! policy — few splits where the KKR matrix is well-conditioned, many
//! near the 0.72 Ry resonance — and compare against fixed splits.
//!
//! Run with `cargo run --release --example adaptive_precision`.

use ozaccel::coordinator::{AdaptivePolicy, DispatchConfig, Dispatcher};
use ozaccel::must::params::{mt_u56_mini, tiny_case};
use ozaccel::must::scf::{ModeSelect, ScfDriver};
use ozaccel::ozaki::ComputeMode;

fn main() -> ozaccel::Result<()> {
    ozaccel::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let mut case = if quick { tiny_case() } else { mt_u56_mini() };
    case.iterations = 1;

    let dispatcher = Dispatcher::new(DispatchConfig::host_only(ComputeMode::Dgemm))?;
    let driver = ScfDriver::new(case, &dispatcher)?;

    let policy = AdaptivePolicy {
        target: 1e-9,
        ..Default::default()
    };
    let run = driver.run(ModeSelect::Adaptive(policy))?;

    println!("per-energy-point split choice (target rel err 1e-9):\n");
    println!("   Re(z)    Im(z)     kappa(est)   splits");
    for p in &run.iterations[0].points {
        let bar = "#".repeat(p.splits_used as usize);
        println!(
            " {:7.4}  {:7.4}  {:10.2e}   {:2}  {bar}",
            p.z.re, p.z.im, p.kappa, p.splits_used
        );
    }
    let mean: f64 = run.iterations[0]
        .points
        .iter()
        .map(|p| p.splits_used as f64)
        .sum::<f64>()
        / run.iterations[0].points.len() as f64;
    println!(
        "\nmean splits {mean:.2} — vs a fixed policy that must run the max\n\
         everywhere; cost scales with s(s+1)/2 per GEMM (paper §4:\n\
         \"minimizing splits while maintaining accuracy is critical\")."
    );
    Ok(())
}
